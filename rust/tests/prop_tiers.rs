//! Property tests for the serving precision ladder (`xai::tiers`).
//!
//! Every approximate rung ships with an analytic error model the
//! coordinator trusts for admission (`modeled_error` vs the request's
//! `max_error`).  These tests hold each rung to its contract with
//! fixed seeds:
//!
//! * the Sampled rung's mean absolute error shrinks as `1/√m` and the
//!   estimator is unbiased across seeds;
//! * the F32Fast IG rung stays inside the trapezoid bound
//!   `TRAP_C/S²` (and the bound is tight enough to be non-vacuous);
//! * the Int8 rung *is* the generic quantized GEMM, so the
//!   `quantized_matmul_error` oracle prices its true deviation at any
//!   shape, and the measured `xai::quantized` oracles pin the modeled
//!   `INT8_SHAPLEY_ERR` constant and the top-1 agreement floor;
//! * the F32Fast saliency rung (raw heatmap) stays inside
//!   `RAW_SALIENCY_ERR` even at the worst pixel.
//!
//! Margins were chosen against measured values with generous
//! headroom, so the assertions are deterministic, not statistical
//! gambles: every seed below is fixed and the measured quantities are
//! reproducible bit-for-bit (modulo f32 accumulation order, orders of
//! magnitude below every threshold).

use xai_accel::hwsim::quantization;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::models::template::TemplateModel;
use xai_accel::trace::NativeEngine;
use xai_accel::util::rng::Rng;
use xai_accel::xai::integrated_gradients::{self as ig, GradientProvider};
use xai_accel::xai::quantized;
use xai_accel::xai::saliency;
use xai_accel::xai::shapley::{self, ValueTable};
use xai_accel::xai::tiers;

/// Seeded batch of dense cooperative games (gaussian value tables),
/// the same construction the tier kernels' unit tests use.
fn seeded_games(n: usize, count: usize, seed: u64) -> Vec<ValueTable> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| ValueTable::new(n, rng.gauss_vec(1 << n)))
        .collect()
}

/// Mean absolute deviation of the sampled estimator from exact Shapley
/// over all games and players, normalized per game by its value range
/// — the scale the `1/√m` model is expressed in.
fn sampled_mean_rel_err(games: &[ValueTable], m: usize, seed: u64) -> f64 {
    let mut eng = NativeEngine::new();
    let est = tiers::shapley_batch_sampled(&mut eng, games, m, seed);
    let mut total = 0f64;
    let mut count = 0usize;
    for (b, g) in games.iter().enumerate() {
        let exact = shapley::shapley_exact(g);
        let lo = g.values.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = g.values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = f64::from((hi - lo).max(1e-6));
        for (i, &e) in exact.iter().enumerate() {
            total += f64::from((est.get(i, b) - e).abs()) / range;
            count += 1;
        }
    }
    total / count as f64
}

#[test]
fn sampled_shapley_error_shrinks_as_sqrt_m() {
    // 200 seeded games, one shared permutation schedule per m.  The
    // measured mean error sits near 0.19x of the 1/sqrt(m) bound at
    // every m, so the bound holds with ~5x headroom and halving it
    // would still pass -- but it must not be vacuous either, hence the
    // lower pin at bound/8.
    let games = seeded_games(8, 200, 0x7155_0001);
    let ms = [8usize, 32, 128, 512];
    let errs: Vec<f64> = ms
        .iter()
        .map(|&m| sampled_mean_rel_err(&games, m, 0x5A3D_5EED))
        .collect();
    for (&m, &err) in ms.iter().zip(&errs) {
        let bound = f64::from(tiers::sampled_shapley_error(m));
        assert!(
            err <= bound,
            "m={m}: measured {err:.5} exceeds modeled bound {bound:.5}"
        );
        assert!(
            err >= bound / 8.0,
            "m={m}: measured {err:.5} makes the {bound:.5} bound vacuous"
        );
    }
    for w in errs.windows(2) {
        assert!(w[1] < w[0], "error must shrink with m: {errs:?}");
    }
    // 16x the samples must buy at least a 2x error reduction (the
    // 1/sqrt(m) model predicts 4x; measured is 4.4x).
    assert!(
        errs[0] / errs[2] >= 2.0,
        "m=8 -> m=128 shrink only {:.2}x",
        errs[0] / errs[2]
    );
}

#[test]
fn sampled_estimator_is_unbiased_across_seeds() {
    // Few samples per estimate (m = 8) so any systematic bias would
    // dominate; 400 seeds so the variance averages out.  Measured
    // worst seed-averaged deviation is 0.009 of the game range; 0.02
    // fails on bias, not on noise (the seeds are fixed, so this is a
    // deterministic computation).
    let games = seeded_games(4, 8, 0x7155_0002);
    let n = 4;
    let seeds = 400u64;
    let m = 8;
    let mut sums = vec![0f64; n * games.len()];
    for s in 0..seeds {
        let mut eng = NativeEngine::new();
        let est = tiers::shapley_batch_sampled(&mut eng, &games, m, 0xB1A5 + s);
        for b in 0..games.len() {
            for i in 0..n {
                sums[b * n + i] += f64::from(est.get(i, b));
            }
        }
    }
    let mut worst = 0f64;
    for (b, g) in games.iter().enumerate() {
        let exact = shapley::shapley_exact(g);
        let lo = g.values.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = g.values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = f64::from(hi - lo);
        for (i, &e) in exact.iter().enumerate() {
            let mean = sums[b * n + i] / seeds as f64;
            worst = worst.max((mean - f64::from(e)).abs() / range);
        }
    }
    assert!(
        worst < 0.02,
        "seed-averaged sampled estimate deviates {worst:.4} of range from exact"
    );
}

/// F(x) = sum_i w_i x_i^3 — on the zero-baseline straight path the
/// gradient is quadratic in the path parameter, the worst smooth case
/// the O(1/S^2) trapezoid model prices: the composite rule's relative
/// error is exactly 1/(2 S^2) for every feature.
struct Cubic {
    w: Vec<f32>,
}

impl GradientProvider for Cubic {
    fn value(&self, x: &[f32]) -> f32 {
        self.w.iter().zip(x).map(|(w, xi)| w * xi * xi * xi).sum()
    }
    fn gradient(&self, x: &[f32]) -> Vec<f32> {
        self.w
            .iter()
            .zip(x)
            .map(|(w, xi)| 3.0 * w * xi * xi)
            .collect()
    }
}

#[test]
fn reduced_step_ig_stays_within_the_trapezoid_bound() {
    // Analytic IG of the cubic is w_i x_i^3; S-step trapezoid gives
    // w_i x_i^3 (1 + 1/(2 S^2)) -- relative error 1/(2 S^2) = bound/4
    // at TRAP_C = 2, independent of w and x.  Magnitudes are floored
    // away from zero so the per-feature ratio is well conditioned.
    let d = 6;
    let reduced = tiers::REDUCED_IG_STEPS;
    let bound = f64::from(tiers::reduced_ig_error(reduced));
    let exact_steps = xai_accel::coordinator::native::IG_STEPS;
    let mut rng = Rng::new(0x7155_0003);
    let floored = |rng: &mut Rng| -> Vec<f32> {
        (0..d)
            .map(|_| {
                let g = rng.gauss_f32();
                g.signum() * (0.5 + g.abs())
            })
            .collect()
    };
    let mut max_rel = 0f64;
    for case in 0..32 {
        let model = Cubic {
            w: floored(&mut rng),
        };
        let x = floored(&mut rng);
        let baseline = vec![0f32; d];
        let mut eng = NativeEngine::new();
        let grads = ig::path_gradients(&mut eng, &model, &x, &baseline, reduced);
        let approx = ig::ig_trapezoid(&mut eng, &grads, &x, &baseline);
        let full = ig::path_gradients(&mut eng, &model, &x, &baseline, exact_steps);
        let exact_rung = ig::ig_trapezoid(&mut eng, &full, &x, &baseline);
        for i in 0..d {
            let truth = f64::from(model.w[i]) * f64::from(x[i]).powi(3);
            let rel = (f64::from(approx[i]) - truth).abs() / truth.abs();
            assert!(
                rel <= bound,
                "case {case} feature {i}: reduced-IG rel err {rel:.5} > bound {bound:.5}"
            );
            max_rel = max_rel.max(rel);
            // The exact rung (4x the steps) must sit strictly below
            // the reduced rung's bound scale -- the ladder is ordered.
            let rel32 = (f64::from(exact_rung[i]) - truth).abs() / truth.abs();
            assert!(
                rel32 <= f64::from(tiers::reduced_ig_error(exact_steps)),
                "case {case} feature {i}: exact-rung rel err {rel32:.6}"
            );
            assert!(rel32 < rel, "more steps must not increase the error");
        }
    }
    assert!(
        max_rel >= bound / 8.0,
        "bound {bound:.5} is vacuous: worst measured {max_rel:.5}"
    );
}

#[test]
fn int8_rung_error_is_priced_by_the_quantized_gemm_oracle_at_odd_shapes() {
    // The Int8 rung IS the generic quantized GEMM: at every (odd n,
    // odd B) shape the fused kernel's output must equal
    // matmul_int8(quantize(T), quantize(V)) exactly, so
    // quantized_matmul_error(T, V) prices its true Frobenius-relative
    // deviation.  The modeled INT8_SHAPLEY_ERR constant holds through
    // n = 11 (measured 0.0073 -> 0.047); by n = 13 the weight matrix's
    // dynamic range outgrows the serving-calibrated constant (measured
    // 0.082) -- the oracle keeps pricing it, which is exactly why the
    // rung carries a measured oracle and not just a constant.
    let shapes: [(usize, usize, u64); 5] = [
        (5, 7, 0x7155_0101),
        (7, 3, 0x7155_0102),
        (9, 5, 0x7155_0103),
        (11, 1, 0x7155_0104),
        (13, 9, 0x7155_0105),
    ];
    let mut oracles = Vec::new();
    for &(n, b, seed) in &shapes {
        let games = seeded_games(n, b, seed);
        let mut eng = NativeEngine::new();
        let got = tiers::shapley_batch_int8(&mut eng, &games);
        let t = shapley::weight_matrix(n);
        let v = Matrix::from_fn(1 << n, b, |s, col| games[col].values[s]);
        let reference =
            quantization::matmul_int8(&quantization::quantize(&t), &quantization::quantize(&v));
        assert_eq!(got.data, reference.data, "n={n} b={b}: rung != quantized GEMM");
        let exact = t.matmul(&v);
        let rel = exact.sub(&got).frobenius_norm() / exact.frobenius_norm().max(1e-12);
        let oracle = quantization::quantized_matmul_error(&t, &v);
        assert!(
            (rel - oracle).abs() < 1e-6,
            "n={n} b={b}: oracle {oracle:.5} misprices measured {rel:.5}"
        );
        if n <= 11 {
            assert!(
                oracle <= tiers::INT8_SHAPLEY_ERR,
                "n={n} b={b}: oracle {oracle:.5} outside modeled {}",
                tiers::INT8_SHAPLEY_ERR
            );
        }
        oracles.push(oracle);
    }
    for w in oracles.windows(2) {
        assert!(
            w[1] > w[0],
            "int8 error must grow with n (T's dynamic range): {oracles:?}"
        );
    }
}

#[test]
fn measured_int8_oracles_pin_the_modeled_constants() {
    // The admission model trusts INT8_SHAPLEY_ERR; the measured
    // oracle at a serving-sized batch must confirm it (measured 0.022
    // vs the 0.08 constant) without being so far below that the
    // constant is meaningless.  Top-1 agreement -- what an analyst
    // reads off the waterfall plot -- is regression-pinned at 0.95
    // (measured 0.99 over 200 games).
    let games = seeded_games(8, 200, 0x7155_0200);
    let err = quantized::shapley_int8_error(&games);
    assert!(
        err <= tiers::INT8_SHAPLEY_ERR,
        "measured int8 error {err:.4} exceeds modeled {}",
        tiers::INT8_SHAPLEY_ERR
    );
    assert!(
        err >= tiers::INT8_SHAPLEY_ERR / 40.0,
        "modeled constant is vacuous: measured {err:.5}"
    );
    let agree = quantized::shapley_int8_top1_agreement(&games);
    assert!(agree >= 0.95, "top-1 agreement regressed to {agree:.3}");
}

#[test]
fn raw_saliency_rung_stays_within_its_modeled_error() {
    // The F32Fast saliency rung serves the raw gradient heatmap; its
    // modeled error is the deviation from the smoothed map over the
    // smoothed map's range.  On the template model the ratio is
    // image-independent (the input-dependent gain scales numerator and
    // denominator alike): measured mean 0.080, worst pixel 5/9 = 0.556
    // -- RAW_SALIENCY_ERR = 0.75 covers even the worst pixel with
    // margin, and the mean check guards against the rung silently
    // becoming exact (a vacuous model).
    let model = TemplateModel::new();
    let img = model.smooth.rows;
    let ones = Matrix::from_fn(img, img, |_, _| 1.0);
    let bound = tiers::RAW_SALIENCY_ERR;
    for class in 0..model.num_classes() {
        let raw = model.grad_heatmap(&ones, class);
        let mut eng = NativeEngine::new();
        let smoothed = saliency::smooth_heatmap(&mut eng, &raw, &model.smooth);
        let lo = smoothed.data.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = smoothed
            .data
            .iter()
            .cloned()
            .fold(f32::NEG_INFINITY, f32::max);
        let range = hi - lo;
        assert!(range > 0.0, "degenerate smoothed map for class {class}");
        let mut mean = 0f32;
        let mut worst = 0f32;
        for (r, s) in raw.data.iter().zip(&smoothed.data) {
            let dev = (r - s).abs() / range;
            mean += dev;
            worst = worst.max(dev);
        }
        mean /= raw.data.len() as f32;
        assert!(
            worst <= bound,
            "class {class}: worst-pixel deviation {worst:.3} > modeled {bound}"
        );
        assert!(
            worst >= bound / 2.0,
            "class {class}: modeled {bound} is vacuous (worst {worst:.3})"
        );
        assert!(
            mean <= bound,
            "class {class}: mean deviation {mean:.3} > modeled {bound}"
        );
        assert!(
            mean > 0.01,
            "class {class}: raw and smoothed maps coincide ({mean:.4})"
        );
    }
}
