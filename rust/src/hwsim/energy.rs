//! Energy accounting and the perf/Watt analyses of Figs. 8–9.
//!
//! The paper reports two normalizations (§IV-C, after Jouppi et al.):
//!  * **total** performance/Watt — includes host-CPU power;
//!  * **incremental** performance/Watt — accelerator power only.
//! and summarizes across workloads with geometric (GM) and weighted
//! arithmetic (WM) means.  This module computes all four from replay
//! reports, plus the per-trial power series behind Fig. 8.

use crate::hwsim::device::CostReport;
use crate::util::stats;

/// One workload's replay on one device, tagged with its work size.
#[derive(Debug, Clone)]
pub struct TrialEnergy {
    /// The replay this energy row was derived from.
    pub report: CostReport,
    /// Weight for WM (the paper weights by workload size; we use flops).
    pub weight: f64,
}

/// perf/Watt of a trial under the chosen accounting.
fn ppw(r: &CostReport, incremental: bool) -> f64 {
    if incremental {
        r.perf_per_watt_incremental()
    } else {
        r.perf_per_watt_total()
    }
}

/// Relative performance/Watt of `dev` over `base`, GM across trials.
pub fn relative_ppw_gm(dev: &[TrialEnergy], base: &[TrialEnergy], incremental: bool) -> f64 {
    assert_eq!(dev.len(), base.len());
    let ratios: Vec<f64> = dev
        .iter()
        .zip(base)
        .map(|(d, b)| ppw(&d.report, incremental) / ppw(&b.report, incremental))
        .collect();
    stats::geometric_mean(&ratios)
}

/// Relative performance/Watt, weighted arithmetic mean across trials.
pub fn relative_ppw_wm(dev: &[TrialEnergy], base: &[TrialEnergy], incremental: bool) -> f64 {
    assert_eq!(dev.len(), base.len());
    let ratios: Vec<f64> = dev
        .iter()
        .zip(base)
        .map(|(d, b)| ppw(&d.report, incremental) / ppw(&b.report, incremental))
        .collect();
    let weights: Vec<f64> = dev.iter().map(|t| t.weight).collect();
    stats::weighted_mean(&ratios, &weights)
}

/// Energy-ratio efficiency for *matched workloads*: when two devices
/// execute the same logical task under different schedules (CPU runs
/// the FFT form, TPU the matmul form), flops/Watt is not comparable —
/// tasks/Joule is.  Relative efficiency of `dev` over `base` is then
/// simply base_energy / dev_energy per trial.
fn energy_of(r: &CostReport, incremental: bool) -> f64 {
    if incremental {
        r.energy_j
    } else {
        r.energy_total_j
    }
}

/// GM of per-trial energy ratios (matched workloads).
pub fn relative_efficiency_gm(dev: &[TrialEnergy], base: &[TrialEnergy], incremental: bool) -> f64 {
    assert_eq!(dev.len(), base.len());
    let ratios: Vec<f64> = dev
        .iter()
        .zip(base)
        .map(|(d, b)| energy_of(&b.report, incremental) / energy_of(&d.report, incremental))
        .collect();
    stats::geometric_mean(&ratios)
}

/// Weighted AM of per-trial energy ratios (matched workloads).
pub fn relative_efficiency_wm(dev: &[TrialEnergy], base: &[TrialEnergy], incremental: bool) -> f64 {
    assert_eq!(dev.len(), base.len());
    let ratios: Vec<f64> = dev
        .iter()
        .zip(base)
        .map(|(d, b)| energy_of(&b.report, incremental) / energy_of(&d.report, incremental))
        .collect();
    let weights: Vec<f64> = dev.iter().map(|t| t.weight).collect();
    stats::weighted_mean(&ratios, &weights)
}

/// Average power draw (kW) per trial — the Fig. 8 series.
pub fn power_series_kw(trials: &[TrialEnergy]) -> Vec<f64> {
    trials
        .iter()
        .map(|t| (t.report.energy_j / t.report.time_s.max(1e-12)) / 1000.0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trial(flops: u64, energy: f64, total: f64, time: f64) -> TrialEnergy {
        TrialEnergy {
            report: CostReport {
                time_s: time,
                overhead_s: 0.0,
                energy_j: energy,
                energy_total_j: total,
                flops,
                avg_power_w: energy / time,
            },
            weight: flops as f64,
        }
    }

    #[test]
    fn gm_of_constant_ratio() {
        let dev = vec![trial(100, 1.0, 2.0, 1.0), trial(100, 1.0, 2.0, 1.0)];
        let base = vec![trial(100, 10.0, 11.0, 1.0), trial(100, 10.0, 11.0, 1.0)];
        // incremental: dev does 100 flops/J, base 10 flops/J => 10x
        assert!((relative_ppw_gm(&dev, &base, true) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn total_vs_incremental_differ() {
        let dev = vec![trial(100, 1.0, 5.0, 1.0)];
        let base = vec![trial(100, 10.0, 10.0, 1.0)];
        let inc = relative_ppw_gm(&dev, &base, true);
        let tot = relative_ppw_gm(&dev, &base, false);
        assert!(inc > tot, "incremental should look better: {inc} vs {tot}");
    }

    #[test]
    fn wm_weights_big_workloads() {
        let mut dev = vec![trial(100, 1.0, 2.0, 1.0), trial(10_000, 1.0, 2.0, 1.0)];
        let base = vec![trial(100, 2.0, 3.0, 1.0), trial(10_000, 50.0, 60.0, 1.0)];
        dev[0].weight = 100.0;
        dev[1].weight = 10_000.0;
        let wm = relative_ppw_wm(&dev, &base, true);
        // big workload ratio = (10000/1)/(10000/50) = 50; small = 2
        assert!(wm > 40.0, "wm {wm} should be pulled toward 50");
    }

    #[test]
    fn power_series_units() {
        let trials = vec![trial(100, 500.0, 600.0, 2.0)];
        let kw = power_series_kw(&trials);
        assert!((kw[0] - 0.25).abs() < 1e-9); // 250 W = 0.25 kW
    }
}
