//! The [`Device`] trait and trace replay.

use crate::hwsim::DeviceKind;
use crate::trace::{Op, OpTrace};

/// Per-op simulated cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpCost {
    /// Fixed dispatch/launch overhead (s).
    pub overhead_s: f64,
    /// Compute + memory time (s).
    pub busy_s: f64,
}

impl OpCost {
    /// Overhead + busy time.
    pub fn total(&self) -> f64 {
        self.overhead_s + self.busy_s
    }
}

/// Replay summary for one trace on one device.
#[derive(Debug, Clone, Default)]
pub struct CostReport {
    /// End-to-end simulated wall time (s).
    pub time_s: f64,
    /// Time lost to dispatch overheads (s).
    pub overhead_s: f64,
    /// Device-only ("incremental") energy (J).
    pub energy_j: f64,
    /// Device + host-CPU ("total") energy (J).
    pub energy_total_j: f64,
    /// Total floating-point work replayed.
    pub flops: u64,
    /// Average device power over the replay (W).
    pub avg_power_w: f64,
}

impl CostReport {
    /// Work per incremental joule — the paper's "incremental perf/Watt".
    pub fn perf_per_watt_incremental(&self) -> f64 {
        self.flops as f64 / self.energy_j.max(1e-12)
    }

    /// Work per total joule (host included) — "total perf/Watt".
    pub fn perf_per_watt_total(&self) -> f64 {
        self.flops as f64 / self.energy_total_j.max(1e-12)
    }
}

/// An analytical accelerator model.
pub trait Device: Send + Sync {
    /// Which device family this model simulates.
    fn kind(&self) -> DeviceKind;

    /// Simulated cost of one op executed on `units` cooperating cores
    /// (data decomposition, Algorithm 1).  `units = 1` is the
    /// undistributed schedule.
    fn op_cost(&self, op: &Op, units: usize) -> OpCost;

    /// Dynamic power while computing (W).
    fn busy_power_w(&self) -> f64;

    /// Static/idle power while dispatching or stalled (W).
    fn idle_power_w(&self) -> f64;

    /// Host-CPU power attributed in "total" energy accounting (W).
    /// Zero for the CPU device itself (it *is* the host).
    fn host_power_w(&self) -> f64;

    /// Number of parallel units available for data decomposition.
    fn max_units(&self) -> usize;

    /// Communication cost of re-assembling a decomposed op across
    /// `units` cores (the `tf.cross_replica_sum` of §III-E).
    fn merge_cost_s(&self, op: &Op, units: usize) -> f64;

    /// Per-op scale on the device's dynamic (busy) power — the energy
    /// lever of reduced-precision pipes.  The default `1.0` keeps every
    /// existing replay bit-identical; devices override it for int8 ops
    /// ([`Op::BatchedMatmulInt8`]), where each MAC costs a fraction of
    /// an fp32 MAC's joules
    /// ([`crate::hwsim::quantization::energy_pj`]).
    fn op_energy_scale(&self, _op: &Op) -> f64 {
        1.0
    }

    /// Replay a full trace on `units` cores.
    fn replay_with_units(&self, trace: &OpTrace, units: usize) -> CostReport {
        let mut time = 0.0f64;
        let mut overhead = 0.0f64;
        let mut busy_energy = 0.0f64;
        for op in &trace.ops {
            let c = self.op_cost(op, units);
            let merge = if units > 1 {
                self.merge_cost_s(op, units)
            } else {
                0.0
            };
            time += c.total() + merge;
            overhead += c.overhead_s + merge;
            // busy energy accumulates per op so reduced-precision ops
            // can draw scaled dynamic power (default scale 1.0 keeps
            // the classic busy_power·busy_s accounting exactly)
            busy_energy += self.busy_power_w() * c.busy_s * self.op_energy_scale(op);
        }
        let energy = busy_energy + self.idle_power_w() * overhead;
        let energy_total = energy + self.host_power_w() * time;
        CostReport {
            time_s: time,
            overhead_s: overhead,
            energy_j: energy,
            energy_total_j: energy_total,
            flops: trace.total_flops(),
            avg_power_w: if time > 0.0 { energy / time } else { 0.0 },
        }
    }

    /// Replay on the device's full complement of cores.
    fn replay(&self, trace: &OpTrace) -> CostReport {
        self.replay_with_units(trace, self.max_units())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::{cpu::CpuSim, gpu::GpuSim, tpu::TpuSim};

    fn big_matmul_trace() -> OpTrace {
        let mut t = OpTrace::new();
        for _ in 0..4 {
            t.push(Op::Matmul {
                m: 1024,
                k: 1024,
                n: 1024,
            });
        }
        t
    }

    fn tiny_trace() -> OpTrace {
        let mut t = OpTrace::new();
        for _ in 0..64 {
            t.push(Op::Elementwise { elems: 64 });
        }
        t
    }

    #[test]
    fn tpu_beats_gpu_beats_cpu_on_large_matmul() {
        let cpu = CpuSim::default().replay(&big_matmul_trace());
        let gpu = GpuSim::default().replay(&big_matmul_trace());
        let tpu = TpuSim::default().replay(&big_matmul_trace());
        assert!(tpu.time_s < gpu.time_s, "tpu {} gpu {}", tpu.time_s, gpu.time_s);
        assert!(gpu.time_s < cpu.time_s, "gpu {} cpu {}", gpu.time_s, cpu.time_s);
    }

    #[test]
    fn gpu_loses_to_cpu_on_tiny_tasks() {
        // Paper §IV-C: "for some special tasks, GPU can even cause more
        // energy consumption than CPU ... for tiny-scale problems".
        let cpu = CpuSim::default().replay(&tiny_trace());
        let gpu = GpuSim::default().replay(&tiny_trace());
        assert!(
            gpu.time_s > cpu.time_s,
            "gpu {} should exceed cpu {} on tiny ops",
            gpu.time_s,
            cpu.time_s
        );
        assert!(gpu.energy_j > cpu.energy_j);
    }

    #[test]
    fn decomposition_helps_tpu() {
        let tpu = TpuSim::default();
        let t = big_matmul_trace();
        let single = tpu.replay_with_units(&t, 1);
        let multi = tpu.replay_with_units(&t, 8);
        assert!(multi.time_s < single.time_s);
    }

    #[test]
    fn energy_total_includes_host() {
        let gpu = GpuSim::default();
        let r = gpu.replay(&big_matmul_trace());
        assert!(r.energy_total_j > r.energy_j);
    }
}
