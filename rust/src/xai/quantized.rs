//! int8-quantized XAI paths — the TPU's "quantification" story (§II-A,
//! §IV-C) executed for real.
//!
//! The paper credits much of the TPU's perf/Watt margin to 8-bit
//! integer arithmetic.  This module runs the structure-vector Shapley
//! matvec and the distillation occlusion sweep through
//! [`hwsim::quantization`]'s int8 matmul and quantifies the accuracy
//! the paper implicitly claims survives quantization ("as long as 8
//! bits can meet the accuracy requirements").

use crate::hwsim::quantization::{self, Quantized};
use crate::linalg::matrix::Matrix;
use crate::xai::shapley::{self, ValueTable};

/// Shapley values through the int8 MXU path: quantize T and the value
/// columns, int8-matmul with int32 accumulation, rescale.
pub fn shapley_int8(games: &[ValueTable]) -> Matrix {
    assert!(!games.is_empty());
    let n = games[0].n;
    let t = shapley::weight_matrix(n);
    let v = Matrix::from_fn(1 << n, games.len(), |s, b| games[b].values[s]);
    quantization::matmul_int8(&quantization::quantize(&t), &quantization::quantize(&v))
}

/// Worst-case Shapley error introduced by int8 quantization, relative
/// to the exact fp32 values, across a batch of games.
pub fn shapley_int8_error(games: &[ValueTable]) -> f32 {
    let q = shapley_int8(games);
    let mut err = 0f32;
    let mut scale = 0f32;
    for (b, g) in games.iter().enumerate() {
        let exact = shapley::shapley_exact(g);
        for (i, &e) in exact.iter().enumerate() {
            err = err.max((q.get(i, b) - e).abs());
            scale = scale.max(e.abs());
        }
    }
    err / scale.max(1e-12)
}

/// Does the int8 path preserve the feature *ranking* (what an analyst
/// actually reads off a waterfall plot)?  Returns the fraction of games
/// whose top feature survives quantization.
pub fn shapley_int8_top1_agreement(games: &[ValueTable]) -> f64 {
    let q = shapley_int8(games);
    let n = games[0].n;
    let mut agree = 0usize;
    for (b, g) in games.iter().enumerate() {
        let exact = shapley::shapley_exact(g);
        let top_exact = exact
            .iter()
            .enumerate()
            .max_by(|a, c| a.1.abs().partial_cmp(&c.1.abs()).unwrap())
            .unwrap()
            .0;
        let top_q = (0..n)
            .max_by(|&a, &c| {
                q.get(a, b)
                    .abs()
                    .partial_cmp(&q.get(c, b).abs())
                    .unwrap()
            })
            .unwrap();
        agree += usize::from(top_exact == top_q);
    }
    agree as f64 / games.len() as f64
}

/// Occlusion contribution factors with the convolution output computed
/// through int8 matmuls (the distilled model quantized for deployment).
pub fn contribution_factors_int8(x: &Matrix, k_spatial: &Quantized, block: usize) -> Matrix {
    let (m, n) = (x.rows, x.cols);
    assert!(m % block == 0 && n % block == 0);
    // dense convolution as an explicit matrix: rows index output pixels,
    // cols index input pixels (circulant structure) — int8-friendly.
    let kd = quantization::dequantize(k_spatial);
    let conv_mat = Matrix::from_fn(m * n, m * n, |o, i| {
        let (or_, oc) = (o / n, o % n);
        let (ir, ic) = (i / n, i % n);
        kd.get((or_ + m - ir) % m, (oc + n - ic) % n)
    });
    let qconv = quantization::quantize(&conv_mat);
    let rows = m / block;
    let cols = n / block;
    let mut out = Matrix::zeros(rows, cols);
    for br in 0..rows {
        for bc in 0..cols {
            let masked = Matrix::from_fn(m * n, 1, |i, _| {
                let (r, c) = (i / n, i % n);
                if r / block == br && c / block == bc {
                    x.get(r, c)
                } else {
                    0.0
                }
            });
            let delta = quantization::matmul_int8(&qconv, &quantization::quantize(&masked));
            out.set(br, bc, delta.frobenius_norm());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::quantization::quantize;
    use crate::util::rng::Rng;

    fn games(n: usize, count: usize, rng: &mut Rng) -> Vec<ValueTable> {
        (0..count)
            .map(|_| ValueTable::new(n, rng.gauss_vec(1 << n)))
            .collect()
    }

    #[test]
    fn int8_shapley_error_is_small() {
        let mut rng = Rng::new(0);
        let gs = games(8, 6, &mut rng);
        let err = shapley_int8_error(&gs);
        assert!(err < 0.08, "relative error {err}");
    }

    #[test]
    fn int8_preserves_top_feature_mostly() {
        let mut rng = Rng::new(1);
        let gs = games(6, 50, &mut rng);
        let agree = shapley_int8_top1_agreement(&gs);
        assert!(agree >= 0.9, "top-1 agreement {agree}");
    }

    #[test]
    fn int8_occlusion_finds_planted_block() {
        let mut x = Matrix::zeros(8, 8);
        for r in 4..8 {
            for c in 0..4 {
                x.set(r, c, 2.5);
            }
        }
        let k = Matrix::identity_kernel(8, 8);
        let contrib = contribution_factors_int8(&x, &quantize(&k), 4);
        // planted block = block (1, 0) in the 2x2 grid
        let mut best = (0, 0);
        let mut bestv = f32::MIN;
        for r in 0..2 {
            for c in 0..2 {
                if contrib.get(r, c) > bestv {
                    bestv = contrib.get(r, c);
                    best = (r, c);
                }
            }
        }
        assert_eq!(best, (1, 0));
    }

    #[test]
    fn int8_matches_fp32_contribution_ordering() {
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(8, 8, |_, _| 2.0 + rng.gauss_f32());
        let k = Matrix::identity_kernel(8, 8);
        let q = contribution_factors_int8(&x, &quantize(&k), 4);
        let mut eng = crate::trace::NativeEngine::new();
        let f = crate::xai::distillation::contribution_factors(&mut eng, &x, &k, 4);
        // rankings must agree
        let rank = |m: &Matrix| {
            let mut idx: Vec<usize> = (0..m.data.len()).collect();
            idx.sort_by(|&a, &b| m.data[b].partial_cmp(&m.data[a]).unwrap());
            idx
        };
        assert_eq!(rank(&q)[0], rank(&f)[0], "top block must survive int8");
    }
}
