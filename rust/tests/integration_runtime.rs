//! Integration: AOT artifacts executed through PJRT must agree with the
//! native Rust oracles — the cross-layer correctness contract.
//!
//! Requires `make artifacts`.  Tests no-op (with a loud message) when
//! the artifacts are missing so `cargo test` still works in a fresh
//! checkout.

use std::path::Path;
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::runtime::ArtifactRegistry;
use xai_accel::trace::NativeEngine;
use xai_accel::util::rng::Rng;
use xai_accel::xai::{distillation, shapley};

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        None
    }
}

#[test]
fn manifest_loads_and_compiles_everything() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(dir).expect("compile all artifacts");
    assert!(reg.len() >= 13, "expected ≥13 artifacts, got {}", reg.len());
    assert_eq!(reg.platform(), "cpu");
    for name in [
        "distill_16x16",
        "occlusion_16x16_b4",
        "shapley_n6_b8",
        "cnn_fwd_b1",
        "ig_cnn_s32",
        "saliency_cnn",
    ] {
        assert!(reg.get(name).is_ok(), "missing {name}");
    }
}

#[test]
fn distill_artifact_matches_native_solver() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["distill_16x16"]).unwrap();
    let exe = reg.get("distill_16x16").unwrap();
    let mut rng = Rng::new(42);
    for _ in 0..5 {
        let x = Matrix::from_fn(16, 16, |_, _| 3.0 + rng.gauss_f32());
        let y = Matrix::from_fn(16, 16, |_, _| rng.gauss_f32());
        let out = exe.run(&[x.data.clone(), y.data.clone()]).unwrap();
        let k_aot = Matrix::from_vec(16, 16, out[0].clone());
        let mut eng = NativeEngine::new();
        let k_native = distillation::distill_fft(&mut eng, &x, &y, 1e-6);
        assert!(
            k_aot.max_abs_diff(&k_native) < 2e-3,
            "AOT vs native disagreement: {}",
            k_aot.max_abs_diff(&k_native)
        );
    }
}

#[test]
fn distill_artifact_recovers_planted_kernel() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["distill_16x16"]).unwrap();
    let exe = reg.get("distill_16x16").unwrap();
    let mut rng = Rng::new(1);
    let x = Matrix::from_fn(16, 16, |_, _| 4.0 + rng.gauss_f32());
    let mut k_true = Matrix::zeros(16, 16);
    k_true.set(0, 0, 0.5);
    k_true.set(2, 3, 0.25);
    let y = circ_conv2(&x, &k_true);
    let out = exe.run(&[x.data.clone(), y.data.clone()]).unwrap();
    let k = Matrix::from_vec(16, 16, out[0].clone());
    assert!(k.max_abs_diff(&k_true) < 0.02, "{}", k.max_abs_diff(&k_true));
}

#[test]
fn shapley_artifact_matches_exact_enumeration() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["shapley_n6_b8"]).unwrap();
    let exe = reg.get("shapley_n6_b8").unwrap();
    let mut rng = Rng::new(7);
    let games: Vec<shapley::ValueTable> = (0..8)
        .map(|_| shapley::ValueTable::new(6, rng.gauss_vec(64)))
        .collect();
    let t = shapley::weight_matrix(6);
    let mut v = vec![0f32; 64 * 8];
    for (b, g) in games.iter().enumerate() {
        for (s, &val) in g.values.iter().enumerate() {
            v[s * 8 + b] = val;
        }
    }
    let out = exe.run(&[t.data.clone(), v]).unwrap();
    for (b, g) in games.iter().enumerate() {
        let exact = shapley::shapley_exact(g);
        for i in 0..6 {
            let got = out[0][i * 8 + b];
            assert!(
                (got - exact[i]).abs() < 1e-3,
                "game {b} phi_{i}: {got} vs {}",
                exact[i]
            );
        }
    }
}

#[test]
fn cnn_artifact_classifies_synthetic_data() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["cnn_fwd_b32"]).unwrap();
    let exe = reg.get("cnn_fwd_b32").unwrap();
    let mut rng = Rng::new(9);
    let batch = xai_accel::data::cifar::sample_batch(32, &mut rng);
    let mut flat = vec![0f32; 32 * 256];
    for (b, s) in batch.iter().enumerate() {
        flat[b * 256..(b + 1) * 256].copy_from_slice(&s.image.data);
    }
    let out = exe.run(&[flat]).unwrap();
    let mut correct = 0;
    for (b, s) in batch.iter().enumerate() {
        let logits = &out[0][b * 4..(b + 1) * 4];
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
            .unwrap()
            .0;
        if pred == s.label {
            correct += 1;
        }
    }
    assert!(correct >= 28, "accuracy {correct}/32 below 87%");
}

#[test]
fn ig_artifact_satisfies_completeness() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["ig_cnn_s32", "cnn_fwd_b1"]).unwrap();
    let ig = reg.get("ig_cnn_s32").unwrap();
    let fwd = reg.get("cnn_fwd_b1").unwrap();
    let mut rng = Rng::new(11);
    let s = xai_accel::data::cifar::sample_class(2, &mut rng);
    let onehot = vec![0f32, 0.0, 1.0, 0.0];
    let baseline = vec![0f32; 256];

    let attr = ig
        .run(&[s.image.data.clone(), baseline.clone(), onehot.clone()])
        .unwrap();
    let total: f32 = attr[0].iter().sum();

    let fx = fwd.run(&[s.image.data.clone()]).unwrap()[0][2];
    let fb = fwd.run(&[baseline]).unwrap()[0][2];
    let expect = fx - fb;
    // 32 trapezoid steps: completeness within a few percent
    assert!(
        (total - expect).abs() < 0.05 * expect.abs().max(1.0),
        "sum(IG)={total} vs F(x)-F(x')={expect}"
    );
}

#[test]
fn saliency_and_ig_heatmaps_are_nonzero_and_finite() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["saliency_cnn", "ig_cnn_s32"]).unwrap();
    let mut rng = Rng::new(13);
    let s = xai_accel::data::cifar::sample_class(0, &mut rng);
    let onehot = vec![1f32, 0.0, 0.0, 0.0];
    let g = reg
        .get("saliency_cnn")
        .unwrap()
        .run(&[s.image.data.clone(), onehot.clone()])
        .unwrap();
    let ig = reg
        .get("ig_cnn_s32")
        .unwrap()
        .run(&[s.image.data.clone(), vec![0f32; 256], onehot])
        .unwrap();
    for (name, v) in [("saliency", &g[0]), ("ig", &ig[0])] {
        let sum: f32 = v.iter().map(|x| x.abs()).sum();
        assert!(sum > 1e-3, "{name} map is all zeros (constant-elision bug?)");
        assert!(v.iter().all(|x| x.is_finite()), "{name} has non-finite values");
    }
}

#[test]
fn executable_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["distill_16x16"]).unwrap();
    let exe = reg.get("distill_16x16").unwrap();
    // wrong arity
    assert!(exe.run(&[vec![0.0; 256]]).is_err());
    // wrong element count
    assert!(exe.run(&[vec![0.0; 100], vec![0.0; 256]]).is_err());
}

#[test]
fn occlusion_artifact_finds_planted_block() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load_subset(dir, &["occlusion_16x16_b4"]).unwrap();
    let exe = reg.get("occlusion_16x16_b4").unwrap();
    let mut x = Matrix::zeros(16, 16);
    for r in 8..12 {
        for c in 4..8 {
            x.set(r, c, 3.0);
        }
    }
    let k = Matrix::identity_kernel(16, 16);
    let out = exe.run(&[x.data.clone(), k.data.clone()]).unwrap();
    let contrib = &out[0]; // 4x4 row-major
    let argmax = contrib
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax, 2 * 4 + 1, "contributions {contrib:?}");
}
