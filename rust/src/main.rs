//! `xai-accel` — the launcher.
//!
//! ```text
//! xai-accel info                      # artifact + device-model summary
//! xai-accel serve   [--executors N] [--lanes tpu,gpu,cpu] [--requests R] [--config FILE]
//! xai-accel explain [--method distill|shapley|ig] [--seed S]
//! xai-accel simulate [--devices cpu,gpu,tpu] [--size N]
//! ```
//!
//! `serve` drives the full coordinator on synthetic traffic; `explain`
//! runs one explanation end-to-end and prints it; `simulate` replays an
//! XAI op trace on the hardware models.

use std::path::PathBuf;
use xai_accel::cli::Args;
use xai_accel::coordinator::{Coordinator, CoordinatorConfig, Request};
use xai_accel::data::{cifar, counters};
use xai_accel::error::Result;
use xai_accel::hwsim::{self, DeviceKind};
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::prelude::NativeEngine;
use xai_accel::util::rng::Rng;
use xai_accel::util::table::{fmt_time, Table};
use xai_accel::xai;

const USAGE: &str = "usage: xai-accel <info|serve|explain|simulate|bench-check> [options]
  info        artifact and device-model summary
  serve       --executors N --requests R --artifact-dir DIR [--config FILE]
              [--lanes tpu,tpu,gpu,cpu]   heterogeneous device lanes
  explain     --method distill|shapley|ig [--seed S] [--artifact-dir DIR]
  simulate    --size N [--devices cpu,gpu,tpu]
  bench-check --baseline FILE --current FILE [--threshold 0.25] [--tracked a,b,c]";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand() {
        Some("info") => run_info(&args),
        Some("serve") => run_serve(&args),
        Some("explain") => run_explain(&args),
        Some("simulate") => run_simulate(&args),
        Some("bench-check") => run_bench_check(&args),
        _ => {
            eprintln!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// CI regression gate: compare a fresh `BENCH_ci.json` against the
/// committed `BENCH_baseline.json` and fail on tracked-kernel
/// regressions beyond the threshold.
fn run_bench_check(args: &Args) -> Result<()> {
    use xai_accel::bench::json;
    let baseline_path = args.get_or("baseline", "BENCH_baseline.json");
    let current_path = args.get_or("current", "BENCH_ci.json");
    let threshold = args.get_f64("threshold", 0.25)?;
    let tracked: Option<Vec<String>> = args.get("tracked").map(|t| {
        t.split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    });
    let baseline = json::load(std::path::Path::new(baseline_path))?;
    let current = json::load(std::path::Path::new(current_path))?;
    let comparisons = json::compare(&baseline, &current, tracked.as_deref(), threshold)?;

    let mut t = Table::new(format!(
        "bench regression gate: p50 vs {baseline_path} (threshold +{:.0}%)",
        threshold * 100.0
    ))
    .header(&["kernel", "baseline", "current", "ratio", "status"]);
    let mut regressions = 0;
    for c in &comparisons {
        if c.regressed {
            regressions += 1;
        }
        // `ratio_*` rows are dimensionless speedups gated against a
        // floor, not latencies — render them as multipliers.
        let is_ratio = c.name.starts_with("ratio_");
        let fmt = |v: f64| {
            if is_ratio {
                format!("{v:.2}x")
            } else {
                fmt_time(v)
            }
        };
        let skipped = c.note.as_deref().is_some_and(|n| n.starts_with("SKIP"));
        t.row(&[
            c.name.clone(),
            fmt(c.baseline_s),
            fmt(c.current_s),
            format!("{:.2}x", c.ratio),
            if c.regressed {
                "REGRESSED"
            } else if skipped {
                "SKIP"
            } else {
                "ok"
            }
            .into(),
        ]);
    }
    t.print();
    for c in &comparisons {
        if let Some(note) = &c.note {
            println!("  {}: {note}", c.name);
        }
    }
    if comparisons.is_empty() {
        println!("(no overlapping kernels compared — record-only run)");
    }
    if regressions > 0 {
        return Err(xai_accel::error::Error::Config(format!(
            "{regressions} tracked kernel(s) regressed more than {:.0}%",
            threshold * 100.0
        )));
    }
    println!("all {} tracked kernels within budget", comparisons.len());
    Ok(())
}

fn artifact_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifact-dir", "artifacts"))
}

fn run_info(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    match xai_accel::runtime::Manifest::load(&dir) {
        Ok(m) => {
            let mut t = Table::new(format!("artifacts in {}", dir.display()))
                .header(&["name", "inputs", "outputs"]);
            for a in &m.artifacts {
                let ins: Vec<String> = a.inputs.iter().map(|s| s.to_string()).collect();
                let outs: Vec<String> = a.outputs.iter().map(|s| s.to_string()).collect();
                t.row(&[a.name.clone(), ins.join(", "), outs.join(", ")]);
            }
            t.print();
        }
        Err(e) => println!("(no artifacts: {e})"),
    }
    let mut t = Table::new("device models").header(&["device", "busy W", "idle W", "units"]);
    for kind in DeviceKind::all() {
        let d = hwsim::device_for(kind);
        t.row(&[
            kind.name().into(),
            format!("{:.0}", d.busy_power_w()),
            format!("{:.0}", d.idle_power_w()),
            format!("{}", d.max_units()),
        ]);
    }
    t.print();
    Ok(())
}

fn run_serve(args: &Args) -> Result<()> {
    let mut config = match args.get("config") {
        Some(path) => xai_accel::config::Config::load(std::path::Path::new(path))?
            .coordinator()?,
        None => CoordinatorConfig::default(),
    };
    config.artifact_dir = artifact_dir(args);
    config.executors = args.get_usize("executors", config.executors)?;
    // heterogeneous plane: --lanes tpu,tpu,gpu,cpu overrides the
    // config file's `lanes` key (and `executors` sizing)
    if let Some(lanes) = args.get("lanes") {
        config.lanes = xai_accel::config::parse_lanes(lanes)?;
    }
    let requests = args.get_usize("requests", 64)?;

    let lanes_desc = if config.lanes.is_empty() {
        format!("{} TPU-class executors", config.executors)
    } else {
        config
            .lanes
            .iter()
            .map(|k| k.name())
            .collect::<Vec<_>>()
            .join(",")
    };
    println!(
        "starting coordinator: lanes [{lanes_desc}], artifacts at {}",
        config.artifact_dir.display()
    );
    let coord = Coordinator::start(config)?;
    let mut rng = Rng::new(42);
    let started = std::time::Instant::now();
    let mut pendings = Vec::new();
    for i in 0..requests {
        let req = synth_request(i, &mut rng);
        pendings.push(coord.submit(req)?);
    }
    let mut ok = 0;
    for p in pendings {
        if p.wait().is_ok() {
            ok += 1;
        }
    }
    let elapsed = started.elapsed().as_secs_f64();
    println!(
        "served {ok}/{requests} requests in {} ({:.1} req/s)",
        fmt_time(elapsed),
        requests as f64 / elapsed
    );
    print!("{}", coord.metrics().report());
    coord.shutdown();
    Ok(())
}

/// Mixed synthetic traffic matching the example workloads.
fn synth_request(i: usize, rng: &mut Rng) -> Request {
    match i % 4 {
        0 => Request::Classify {
            image: cifar::sample_class(i % cifar::NUM_CLASSES, rng).image,
        },
        1 => {
            let x = Matrix::from_fn(16, 16, |_, _| 3.0 + rng.gauss_f32());
            let y = circ_conv2(&x, &Matrix::identity_kernel(16, 16));
            Request::Distill { x, y }
        }
        2 => {
            let s = counters::sample(counters::ProgramClass::Spectre, rng);
            let game = spectre_game(&s);
            Request::Shapley {
                n: counters::N_FEATURES,
                values: game,
                names: counters::FEATURES.iter().map(|s| s.to_string()).collect(),
            }
        }
        _ => {
            let img = cifar::sample_class(i % cifar::NUM_CLASSES, rng).image;
            Request::IntGrad {
                baseline: Matrix::zeros(img.rows, img.cols),
                class: i % cifar::NUM_CLASSES,
                image: img,
            }
        }
    }
}

/// Value table for the detector game: v(S) = score with features
/// outside S neutralized to the benign mean.
fn spectre_game(sample: &counters::CounterSample) -> Vec<f32> {
    let benign = [0.15f32, 0.10, 0.50, 0.20, 0.40, 0.25];
    (0..1usize << counters::N_FEATURES)
        .map(|s| {
            let mut f = benign;
            for i in 0..counters::N_FEATURES {
                if s & (1 << i) != 0 {
                    f[i] = sample.features[i];
                }
            }
            counters::detector_score(&f)
        })
        .collect()
}

fn run_explain(args: &Args) -> Result<()> {
    let seed = args.get_usize("seed", 7)? as u64;
    let mut rng = Rng::new(seed);
    match args.get_or("method", "distill") {
        "distill" => {
            let x = Matrix::from_fn(16, 16, |_, _| 3.0 + rng.gauss_f32());
            let y = circ_conv2(&x, &Matrix::identity_kernel(16, 16));
            let mut eng = NativeEngine::new();
            let (k, attr) = xai::distillation::explain(&mut eng, &x, &y, 4, 1e-6);
            println!("distilled kernel K[0,0] = {:.4} (expect ~1.0)", k.get(0, 0));
            println!("top block: {}", attr.names[attr.top_feature()]);
            println!("{}", attr.waterfall(30));
        }
        "shapley" => {
            let s = counters::sample(counters::ProgramClass::Spectre, &mut rng);
            let game = xai::shapley::ValueTable::new(
                counters::N_FEATURES,
                spectre_game(&s),
            );
            let mut eng = NativeEngine::new();
            let attr = xai::shapley::explain(&mut eng, &game, &counters::FEATURES);
            println!("SHAP for a Spectre sample (score {:.3}):", counters::detector_score(&s.features));
            println!("{}", attr.waterfall(30));
        }
        "ig" => {
            let dir = artifact_dir(args);
            let reg = xai_accel::runtime::ArtifactRegistry::load_subset(
                &dir,
                &["ig_cnn_s32", "cnn_fwd_b1"],
            )?;
            let sample = cifar::sample_class(2, &mut rng);
            let exe = reg.get("ig_cnn_s32")?;
            let onehot = {
                let mut v = vec![0f32; 4];
                v[sample.label] = 1.0;
                v
            };
            let baseline = vec![0f32; 256];
            let out = exe.run(&[sample.image.data.clone(), baseline, onehot])?;
            let heat = Matrix::from_vec(16, 16, out[0].clone());
            println!("IG heatmap for a class-{} image:", sample.label);
            print_heatmap(&heat);
        }
        other => {
            eprintln!("unknown method '{other}'");
        }
    }
    Ok(())
}

fn print_heatmap(m: &Matrix) {
    let maxabs = m.data.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-9);
    const LEVELS: [char; 5] = [' ', '.', '+', '*', '#'];
    for r in 0..m.rows {
        let line: String = (0..m.cols)
            .map(|c| {
                let t = (m.get(r, c).abs() / maxabs * (LEVELS.len() - 1) as f32).round();
                LEVELS[(t as usize).min(LEVELS.len() - 1)]
            })
            .collect();
        println!("  {line}");
    }
}

fn run_simulate(args: &Args) -> Result<()> {
    let n = args.get_usize("size", 256)?;
    let devices: Vec<DeviceKind> = args
        .get_or("devices", "cpu,gpu,tpu")
        .split(',')
        .filter_map(|d| match d.trim() {
            "cpu" => Some(DeviceKind::Cpu),
            "gpu" => Some(DeviceKind::Gpu),
            "tpu" => Some(DeviceKind::Tpu),
            _ => None,
        })
        .collect();

    // Record the distillation pipeline's op trace at this size.
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(n.min(64), n.min(64), |_, _| 2.0 + rng.gauss_f32());
    let y = circ_conv2(&x, &Matrix::identity_kernel(x.rows, x.cols));
    let mut eng = NativeEngine::new();
    xai::distillation::distill_fft(&mut eng, &x, &y, 1e-6);
    let mut trace = eng.take_trace();
    // scale trace to the requested size analytically
    if n > 64 {
        trace.clear();
        trace.push(xai_accel::trace::Op::Dft2Matmul { m: n, n });
        trace.push(xai_accel::trace::Op::Dft2Matmul { m: n, n });
        trace.push(xai_accel::trace::Op::HadamardDiv { m: n, n });
        trace.push(xai_accel::trace::Op::Dft2Matmul { m: n, n });
    }

    let mut t = Table::new(format!("distillation solve at {n}x{n}"))
        .header(&["device", "time", "energy (J)", "perf/W vs CPU"]);
    let cpu_report = hwsim::device_for(DeviceKind::Cpu).replay(&trace);
    for kind in devices {
        let r = hwsim::device_for(kind).replay(&trace);
        t.row(&[
            kind.name().into(),
            fmt_time(r.time_s),
            format!("{:.3}", r.energy_j),
            format!(
                "{:.1}x",
                r.perf_per_watt_incremental() / cpu_report.perf_per_watt_incremental()
            ),
        ]);
    }
    t.print();
    Ok(())
}
