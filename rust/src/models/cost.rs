//! Training / inference cost traces for Table II.
//!
//! Table II reports 10-epoch training and testing times per device.
//! We reconstruct those as op traces over the model's layer stack
//! (forward + backward per step, forward per test sample) and replay
//! them on the simulators — same mechanism as the XAI tables, so the
//! relative device ordering is produced by the models, not hard-coded.

use crate::models::layers::{LayerSpec, ModelSpec};
use crate::trace::{Op, OpTrace};

/// Map a layer onto its matrix-op form (what an accelerator executes).
/// Convolutions lower to im2col matmuls; dense layers are matmuls.
fn layer_ops(layer: &LayerSpec, batch: usize) -> Op {
    match *layer {
        LayerSpec::Conv {
            h,
            w,
            cin,
            cout,
            k,
            stride,
        } => {
            let oh = h / stride;
            let ow = w / stride;
            Op::Matmul {
                m: batch * oh * ow,
                k: cin * k * k,
                n: cout,
            }
        }
        LayerSpec::Dense { cin, cout } => Op::Matmul {
            m: batch,
            k: cin,
            n: cout,
        },
        LayerSpec::Pool { h, w, c, k } => Op::Elementwise {
            elems: batch * h * w * c * k * k / 4,
        },
        LayerSpec::Elementwise { h, w, c } => Op::Elementwise {
            elems: batch * h * w * c,
        },
    }
}

/// Forward-pass trace for one batch.
pub fn forward_trace(model: &ModelSpec, batch: usize) -> OpTrace {
    let mut t = OpTrace::new();
    for layer in &model.layers {
        t.push(layer_ops(layer, batch));
    }
    t
}

/// Forward + backward trace for one training step (backward ≈ 2×
/// forward: grads w.r.t. weights and w.r.t. activations).
pub fn train_step_trace(model: &ModelSpec, batch: usize) -> OpTrace {
    let mut t = forward_trace(model, batch);
    let back = forward_trace(model, batch);
    t.extend(&back);
    t.extend(&back);
    t
}

/// Trace for `epochs` of training on `samples` examples at `batch`.
pub fn training_trace(model: &ModelSpec, epochs: usize, samples: usize, batch: usize) -> OpTrace {
    let steps = epochs * samples.div_ceil(batch);
    let step = train_step_trace(model, batch);
    let mut t = OpTrace::new();
    // Collapse identical steps by scaling op counts: replaying the
    // structure once per step would blow up the trace length.
    for op in &step.ops {
        for _ in 0..1 {
            t.push(*op);
        }
    }
    // scale: repeat the per-step ops `steps` times logically
    let mut scaled = OpTrace::new();
    for _ in 0..steps.min(64) {
        scaled.extend(&t);
    }
    if steps > 64 {
        // represent the remaining steps by a proportional model op
        let rep = (steps - 64) as u64;
        let f = t.total_flops() * rep;
        scaled.push(Op::ModelForward {
            count: 1,
            flops_per_fwd: f,
        });
    }
    scaled
}

/// Trace for evaluating `samples` test examples at `batch`.
pub fn testing_trace(model: &ModelSpec, samples: usize, batch: usize) -> OpTrace {
    let steps = samples.div_ceil(batch);
    let fwd = forward_trace(model, batch);
    let mut t = OpTrace::new();
    for _ in 0..steps.min(64) {
        t.extend(&fwd);
    }
    if steps > 64 {
        t.push(Op::ModelForward {
            count: 1,
            flops_per_fwd: fwd.total_flops() * (steps - 64) as u64,
        });
    }
    t
}

/// A convergence model for Table II's accuracy column: accuracy after
/// `epochs` approaches the model's ceiling with a per-model rate.
/// Coefficients fit the qualitative behaviour the paper reports.
pub fn simulated_accuracy(model: &ModelSpec, epochs: usize, device_boost: f64) -> f64 {
    let (ceiling, rate) = match model.name {
        "VGG19" => (0.945, 0.55),
        "VGG16" => (0.935, 0.55),
        "ResNet50" => (0.88, 0.35),
        _ => (0.99, 0.9),
    };
    let acc = ceiling * (1.0 - (-(rate * epochs as f64)).exp());
    (acc + device_boost).min(0.999)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Benchmark;

    #[test]
    fn forward_trace_flops_match_spec_order() {
        let spec = Benchmark::MicroCnn.spec();
        let t = forward_trace(&spec, 1);
        // im2col matmul flops == conv flops (same MACs)
        let ratio = t.total_flops() as f64 / spec.total_flops() as f64;
        assert!((0.8..1.2).contains(&ratio), "{ratio}");
    }

    #[test]
    fn train_is_3x_forward() {
        let spec = Benchmark::MicroCnn.spec();
        let f = forward_trace(&spec, 8).total_flops();
        let t = train_step_trace(&spec, 8).total_flops();
        assert_eq!(t, 3 * f);
    }

    #[test]
    fn resnet_costs_more_than_vgg_at_same_resolution() {
        // At the paper's respective input sizes ResNet50(64²) is the
        // heavier workload — matching Table II's much longer times.
        let v = forward_trace(&Benchmark::Vgg19.spec(), 32).total_flops();
        let r = forward_trace(&Benchmark::ResNet50.spec(), 32).total_flops();
        assert!(r > v / 4, "r={r} v={v}"); // same ballpark or heavier
    }

    #[test]
    fn accuracy_converges() {
        let spec = Benchmark::Vgg19.spec();
        let early = simulated_accuracy(&spec, 1, 0.0);
        let late = simulated_accuracy(&spec, 10, 0.0);
        assert!(late > early);
        assert!(late < 1.0);
    }
}
