//! Shapley value analysis (paper §II-C, §III-B).
//!
//! Three implementations spanning the paper's comparison space:
//!
//! * [`shapley_exact`] — direct Eq. 2 evaluation over all 2ⁿ subsets:
//!   the CPU baseline ("numerous iterations").
//! * [`shapley_matrix_form`] — the transformed form: build the n×2ⁿ
//!   structure-vector weight matrix T once, then φ = T·v is a single
//!   matmul batched over games (§III-B, after Wang et al.) — this is
//!   what the TPU runs.
//! * [`shapley_sampled`] — permutation-sampling approximation, the
//!   standard scalable fallback, used for the large-n ablation.

use crate::linalg::matrix::Matrix;
use crate::trace::NativeEngine;
use crate::util::rng::Rng;
use crate::xai::attribution::Attribution;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// A cooperative game given as a dense value table: `values[s]` is
/// v(S) where bit i of `s` means player i is in S.
#[derive(Debug, Clone)]
pub struct ValueTable {
    /// Number of players.
    pub n: usize,
    /// Value of every coalition, indexed by subset bitmask (2^n entries).
    pub values: Vec<f32>,
}

impl ValueTable {
    /// A value table for `n` players (panics unless `values.len() == 2^n`).
    pub fn new(n: usize, values: Vec<f32>) -> Self {
        assert_eq!(values.len(), 1usize << n, "need 2^n values");
        Self { n, values }
    }

    /// Build the table by evaluating a set function over all subsets.
    pub fn from_fn(n: usize, mut v: impl FnMut(usize) -> f32) -> Self {
        let values = (0..1usize << n).map(|s| v(s)).collect();
        Self { n, values }
    }
}

fn factorials(n: usize) -> Vec<f64> {
    let mut f = vec![1.0f64; n + 1];
    for i in 1..=n {
        f[i] = f[i - 1] * i as f64;
    }
    f
}

/// Exact Shapley values by subset enumeration (Eq. 2). O(n·2ⁿ).
pub fn shapley_exact(game: &ValueTable) -> Vec<f32> {
    let n = game.n;
    let fact = factorials(n);
    let mut phi = vec![0f64; n];
    for i in 0..n {
        let bit = 1usize << i;
        for s in 0..(1usize << n) {
            if s & bit != 0 {
                continue;
            }
            let size = s.count_ones() as usize;
            let w = fact[size] * fact[n - size - 1] / fact[n];
            phi[i] += w * (game.values[s | bit] - game.values[s]) as f64;
        }
    }
    phi.into_iter().map(|v| v as f32).collect()
}

/// The n×2ⁿ structure-vector weight matrix T with φ = T·v.
///
/// Row i carries +w(|S|−1) at subsets containing i and −w(|S|) at
/// subsets missing i, so the entire Shapley computation collapses into
/// one matrix-vector product (the paper's TPU-form).
pub fn weight_matrix(n: usize) -> Matrix {
    let fact = factorials(n);
    Matrix::from_fn(n, 1 << n, |i, s| {
        let size = s.count_ones() as usize;
        if s & (1 << i) != 0 {
            (fact[size - 1] * fact[n - size] / fact[n]) as f32
        } else {
            -(fact[size] * fact[n - size - 1] / fact[n]) as f32
        }
    })
}

/// Matrix-form Shapley for a batch of games sharing the same n:
/// φ = T · V with V the 2ⁿ×B stacked value columns.  Returns n×B.
pub fn shapley_matrix_form(eng: &mut NativeEngine, games: &[ValueTable]) -> Matrix {
    assert!(!games.is_empty());
    let n = games[0].n;
    assert!(games.iter().all(|g| g.n == n));
    let t = weight_matrix(n);
    let v = Matrix::from_fn(1 << n, games.len(), |s, b| games[b].values[s]);
    eng.matmul(&t, &v)
}

fn weight_matrix_cache() -> &'static Mutex<HashMap<usize, Arc<Matrix>>> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<Matrix>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Largest player count the process-wide T cache retains.  T is an
/// n×2ⁿ f32 matrix — n = 16 is ~4 MB; pinning anything bigger forever
/// in a static map would let a handful of odd-sized requests exhaust
/// serving memory, so larger games build T per call instead.
pub const MAX_CACHED_PLAYERS: usize = 16;

/// Process-wide cached structure-vector matrix T for `n` players —
/// built once per n, like the `linalg::fft` plan cache, so the fused
/// serving path pays the O(n·2ⁿ) construction on the first batch only.
/// Above [`MAX_CACHED_PLAYERS`] the matrix is built fresh (not
/// retained).
pub fn weight_matrix_cached(n: usize) -> Arc<Matrix> {
    if n > MAX_CACHED_PLAYERS {
        return Arc::new(weight_matrix(n));
    }
    if let Some(t) = weight_matrix_cache().lock().unwrap().get(&n) {
        return t.clone();
    }
    // built outside the lock: a lost race only costs one extra build
    let built = Arc::new(weight_matrix(n));
    weight_matrix_cache()
        .lock()
        .unwrap()
        .entry(n)
        .or_insert(built)
        .clone()
}

/// Fused batched Shapley: the whole batch as ONE GEMM, φ = T·V with the
/// cached T and V the 2ⁿ×B stacked value columns (recorded as a
/// [`crate::trace::Op::BatchedMatmul`] so the device models price the
/// fused dispatch).  Numerically identical to [`shapley_matrix_form`]
/// — and to running it per game — since the per-column accumulation
/// order is the same.  Returns n×B.
pub fn shapley_batch_fused(eng: &mut NativeEngine, games: &[ValueTable]) -> Matrix {
    assert!(!games.is_empty());
    let n = games[0].n;
    assert!(games.iter().all(|g| g.n == n));
    let t = weight_matrix_cached(n);
    let v = Matrix::from_fn(1 << n, games.len(), |s, b| games[b].values[s]);
    eng.batched_matmul(&t, &v, games.len())
}

/// Batched Shapley executed by a typed collective group: the 2ⁿ
/// value-table rows band across the group members (the k dimension of
/// φ = T·V), each member contracting its row band of T's columns
/// against its band of stacked value columns, with the partial φ
/// matrices ring-summed back.  Recorded as one
/// [`crate::trace::Op::ShardedMatmulGrouped`] carrying the member
/// classes plus the merging all-gather, so the hwsim pool prices the
/// banded GEMM on the group's actual links.  Numerically within 1e-4
/// of [`shapley_batch_fused`] (the band-partial sums re-associate the
/// k-accumulation).  Returns n×B.
pub fn shapley_batch_collective(
    eng: &mut NativeEngine,
    games: &[ValueTable],
    plan: &crate::linalg::shard::CollectivePlan,
) -> Matrix {
    assert!(!games.is_empty());
    let n = games[0].n;
    assert!(games.iter().all(|g| g.n == n));
    let rows = 1usize << n;
    plan.validate(rows);
    let b = games.len();
    let group = crate::trace::GroupSpec::new(&plan.members);
    eng.trace.push(crate::trace::Op::ShardedMatmulGrouped {
        m: n,
        k: rows,
        n: b,
        group,
    });
    // partial n×B φ matrices gather over the group's links
    eng.trace.push(crate::trace::Op::AllGatherGrouped {
        bytes: 4 * (n * b) as u64,
        group,
    });
    let t = weight_matrix_cached(n);
    let mut phi = Matrix::zeros(n, b);
    for band in &plan.bands {
        // member's band of value rows: partial φ += T[:, band]·V[band, :]
        for s in band.start..band.start + band.len {
            for i in 0..n {
                let w = t.get(i, s);
                for (col, game) in games.iter().enumerate() {
                    phi.set(i, col, phi.get(i, col) + w * game.values[s]);
                }
            }
        }
    }
    phi
}

/// Permutation-sampling approximation with `samples` random orders.
pub fn shapley_sampled(game: &ValueTable, samples: usize, rng: &mut Rng) -> Vec<f32> {
    let n = game.n;
    let mut phi = vec![0f64; n];
    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..samples {
        rng.shuffle(&mut order);
        let mut s = 0usize;
        for &i in &order {
            let before = game.values[s];
            s |= 1 << i;
            phi[i] += (game.values[s] - before) as f64;
        }
    }
    phi.into_iter()
        .map(|v| (v / samples as f64) as f32)
        .collect()
}

/// Explain a prediction with named features.
pub fn explain(
    eng: &mut NativeEngine,
    game: &ValueTable,
    names: &[&str],
) -> Attribution {
    assert_eq!(names.len(), game.n);
    let phi = shapley_matrix_form(eng, std::slice::from_ref(game));
    Attribution::new(
        names.iter().map(|s| s.to_string()).collect(),
        (0..game.n).map(|i| phi.get(i, 0)).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn random_game(n: usize, rng: &mut Rng) -> ValueTable {
        ValueTable::new(n, rng.gauss_vec(1 << n))
    }

    #[test]
    fn matrix_form_matches_exact() {
        check("T·v == exact Shapley", 20, |rng: &mut Rng| {
            let n = rng.int_range(2, 9) as usize;
            let g = random_game(n, rng);
            let exact = shapley_exact(&g);
            let mut eng = NativeEngine::new();
            let mf = shapley_matrix_form(&mut eng, std::slice::from_ref(&g));
            for i in 0..n {
                assert!(
                    (exact[i] - mf.get(i, 0)).abs() < 1e-3,
                    "i={i}: {} vs {}",
                    exact[i],
                    mf.get(i, 0)
                );
            }
        });
    }

    #[test]
    fn efficiency_axiom() {
        check("sum(phi) = v(N) - v(0)", 20, |rng: &mut Rng| {
            let n = rng.int_range(2, 8) as usize;
            let g = random_game(n, rng);
            let phi = shapley_exact(&g);
            let total: f32 = phi.iter().sum();
            let expect = g.values[(1 << n) - 1] - g.values[0];
            assert!((total - expect).abs() < 1e-3);
        });
    }

    #[test]
    fn dummy_player_axiom() {
        // player n-1 never changes the value => phi = 0
        let n = 5;
        let g = ValueTable::from_fn(n, |s| (s & 0b0111).count_ones() as f32);
        let phi = shapley_exact(&g);
        assert!(phi[3].abs() < 1e-6);
        assert!(phi[4].abs() < 1e-6);
    }

    #[test]
    fn symmetry_axiom() {
        // fully symmetric game: everyone gets the same share
        let n = 4;
        let g = ValueTable::from_fn(n, |s| (s.count_ones() as f32).powi(2));
        let phi = shapley_exact(&g);
        for i in 1..n {
            assert!((phi[i] - phi[0]).abs() < 1e-5);
        }
    }

    #[test]
    fn table_i_worked_example() {
        // The paper's Table I: 3 features, marginal contributions of
        // feature 1 averaged over all 6 orders.  Use an additive game
        // v(S) = sum of (i+1) for i in S: phi_i must equal i+1.
        let g = ValueTable::from_fn(3, |s| {
            (0..3).filter(|i| s & (1 << i) != 0).map(|i| i as f32 + 1.0).sum()
        });
        let phi = shapley_exact(&g);
        assert!((phi[0] - 1.0).abs() < 1e-5);
        assert!((phi[1] - 2.0).abs() < 1e-5);
        assert!((phi[2] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn sampling_converges() {
        let mut rng = Rng::new(0);
        let g = random_game(6, &mut rng);
        let exact = shapley_exact(&g);
        let approx = shapley_sampled(&g, 4000, &mut rng);
        for i in 0..6 {
            assert!(
                (exact[i] - approx[i]).abs() < 0.15,
                "i={i}: {} vs {}",
                exact[i],
                approx[i]
            );
        }
    }

    #[test]
    fn fused_batch_matches_per_game_matrix_form() {
        // The tentpole's correctness property: T·V stacking against
        // shapley_matrix_form run per game, across random n and B.
        check("fused T·V == per-game matrix form", 20, |rng: &mut Rng| {
            let n = rng.int_range(2, 11) as usize;
            let b = rng.int_range(1, 9) as usize;
            let games: Vec<ValueTable> = (0..b).map(|_| random_game(n, rng)).collect();
            let mut fused_eng = NativeEngine::new();
            let fused = shapley_batch_fused(&mut fused_eng, &games);
            assert_eq!((fused.rows, fused.cols), (n, b));
            // exactly one fused op was recorded
            assert_eq!(fused_eng.trace.ops.len(), 1);
            for (col, g) in games.iter().enumerate() {
                let mut eng = NativeEngine::new();
                let lone = shapley_matrix_form(&mut eng, std::slice::from_ref(g));
                for i in 0..n {
                    let d = (fused.get(i, col) - lone.get(i, 0)).abs();
                    assert!(d < 1e-5, "n={n} b={b} i={i} col={col}: diff {d}");
                }
            }
        });
    }

    #[test]
    fn collective_banding_matches_fused() {
        use crate::hwsim::DeviceKind::{Cpu, Gpu, Tpu};
        use crate::linalg::shard::CollectivePlan;
        use crate::trace::Op;
        // Banding the 2ⁿ value rows across a typed group must agree
        // with the fused single-device GEMM for every group shape the
        // planner can emit: even 2-way, 3-way, and a weighted
        // mixed-kind plan.
        check("collective T·V == fused T·V", 20, |rng: &mut Rng| {
            let n = rng.int_range(3, 11) as usize;
            let b = rng.int_range(1, 9) as usize;
            let games: Vec<ValueTable> = (0..b).map(|_| random_game(n, rng)).collect();
            let mut fused_eng = NativeEngine::new();
            let fused = shapley_batch_fused(&mut fused_eng, &games);
            let rows = 1usize << n;
            let plans = [
                CollectivePlan::balanced(rows, &[Tpu, Tpu]),
                CollectivePlan::balanced(rows, &[Tpu, Gpu, Cpu]),
                CollectivePlan::from_weights(rows, &[Gpu, Tpu, Tpu], &[1.0, 3.0, 3.0]),
            ];
            for plan in &plans {
                let mut eng = NativeEngine::new();
                let phi = shapley_batch_collective(&mut eng, &games, plan);
                assert_eq!((phi.rows, phi.cols), (n, b));
                // the group op stream: one banded GEMM + the φ merge
                assert_eq!(eng.trace.ops.len(), 2);
                match (&eng.trace.ops[0], &eng.trace.ops[1]) {
                    (
                        Op::ShardedMatmulGrouped { m, k, n: cols, group },
                        Op::AllGatherGrouped { bytes, group: g2 },
                    ) => {
                        assert_eq!((*m, *k, *cols), (n, rows, b));
                        assert_eq!(group.len(), plan.len());
                        assert_eq!(group, g2);
                        assert_eq!(*bytes, 4 * (n * b) as u64);
                    }
                    other => panic!("unexpected op stream: {other:?}"),
                }
                for i in 0..n {
                    for col in 0..b {
                        let d = (phi.get(i, col) - fused.get(i, col)).abs();
                        assert!(d < 1e-4, "n={n} b={b} i={i} col={col}: diff {d}");
                    }
                }
            }
        });
    }

    #[test]
    fn weight_matrix_cache_shares_and_matches() {
        let a = weight_matrix_cached(7);
        let b = weight_matrix_cached(7);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(*a, weight_matrix(7));
    }

    #[test]
    fn batch_matrix_form() {
        let mut rng = Rng::new(1);
        let games: Vec<ValueTable> = (0..4).map(|_| random_game(5, &mut rng)).collect();
        let mut eng = NativeEngine::new();
        let phi = shapley_matrix_form(&mut eng, &games);
        assert_eq!((phi.rows, phi.cols), (5, 4));
        for (b, g) in games.iter().enumerate() {
            let exact = shapley_exact(g);
            for i in 0..5 {
                assert!((phi.get(i, b) - exact[i]).abs() < 1e-3);
            }
        }
        // a single matmul was recorded — the paper's point
        assert_eq!(eng.trace.ops.len(), 1);
    }
}
