//! Integration: the fused-batch native backend through the LIVE
//! coordinator under mixed-kind concurrent traffic, asserting that
//! batched execution returns the same answers as per-request execution
//! (bit-identical in principle; gated at 1e-5).  No artifacts needed —
//! these tests run the `BackendMode::NativeOnly` fused kernel layer.

use std::time::Duration;
use xai_accel::coordinator::{
    BackendMode, Coordinator, CoordinatorConfig, NativeBackend, Request, Response,
};
use xai_accel::data::cifar;
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::util::rng::Rng;

fn native_coordinator(executors: usize) -> Coordinator {
    let mut config = CoordinatorConfig::default();
    config.executors = executors;
    config.backend = BackendMode::NativeOnly;
    // generous flush window so concurrent submits actually batch
    config.policy.max_wait = Duration::from_millis(10);
    Coordinator::start(config).expect("native coordinator start")
}

fn mixed_request(i: usize, rng: &mut Rng) -> Request {
    match i % 5 {
        0 => Request::Classify {
            image: cifar::sample_class(i % 4, rng).image,
        },
        1 => Request::Shapley {
            n: 6,
            values: rng.gauss_vec(64),
            names: (0..6).map(|j| format!("f{j}")).collect(),
        },
        2 => Request::Saliency {
            image: cifar::sample_class(i % 4, rng).image,
            class: i % 4,
        },
        3 => Request::IntGrad {
            image: cifar::sample_class(i % 4, rng).image,
            baseline: Matrix::zeros(16, 16),
            class: i % 4,
        },
        _ => {
            let x = Matrix::from_fn(16, 16, |_, _| 4.0 + rng.gauss_f32());
            let y = circ_conv2(&x, &Matrix::identity_kernel(16, 16));
            Request::Distill { x, y }
        }
    }
}

fn assert_responses_close(got: &Response, want: &Response, tol: f32) {
    match (got, want) {
        (Response::Logits(a), Response::Logits(b)) => {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < tol, "logits {x} vs {y}");
            }
        }
        (Response::Attribution(a), Response::Attribution(b)) => {
            assert_eq!(a.names, b.names);
            for (x, y) in a.scores.iter().zip(&b.scores) {
                assert!((x - y).abs() < tol, "scores {x} vs {y}");
            }
        }
        (Response::Heatmap(a), Response::Heatmap(b)) => {
            assert!(a.max_abs_diff(b) < tol, "heatmap diff {}", a.max_abs_diff(b));
        }
        (
            Response::Distillation {
                kernel: ka,
                contributions: ca,
            },
            Response::Distillation {
                kernel: kb,
                contributions: cb,
            },
        ) => {
            assert!(ka.max_abs_diff(kb) < tol);
            assert!(ca.max_abs_diff(cb) < tol);
        }
        other => panic!("response kinds differ: {other:?}"),
    }
}

/// The tentpole equivalence: mixed-kind concurrent traffic through the
/// batching coordinator returns exactly what per-request execution
/// returns.
#[test]
fn fused_batches_match_per_request_execution() {
    let coord = native_coordinator(2);
    let oracle = NativeBackend::new();
    let mut rng = Rng::new(42);
    let requests: Vec<Request> = (0..60).map(|i| mixed_request(i, &mut rng)).collect();
    let pendings: Vec<_> = requests
        .iter()
        .map(|r| coord.submit(r.clone()).unwrap())
        .collect();
    for (req, pending) in requests.iter().zip(pendings) {
        let got = pending.wait().expect("request must succeed");
        let want = oracle.execute_single(req).expect("oracle must succeed");
        assert_responses_close(&got, &want, 1e-5);
    }
    // traffic of five kinds across two executors actually batched
    assert!(coord.metrics().mean_batch_size() > 1.0);
    assert_eq!(coord.metrics().completed(), 60);
    coord.shutdown();
}

/// Submitting from several client threads at once must not corrupt
/// routing: every response still matches its own request's oracle.
#[test]
fn concurrent_clients_get_their_own_answers() {
    let coord = std::sync::Arc::new(native_coordinator(2));
    let oracle = std::sync::Arc::new(NativeBackend::new());
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let coord = coord.clone();
        let oracle = oracle.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..12 {
                let req = mixed_request(i + t as usize, &mut rng);
                let got = coord.call(req.clone()).expect("request ok");
                let want = oracle.execute_single(&req).unwrap();
                assert_responses_close(&got, &want, 1e-5);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    match std::sync::Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => panic!("coordinator still shared"),
    }
}

/// Invalid members of a batch error individually while their batchmates
/// succeed (the per-request fallback inside the fused path).
#[test]
fn invalid_requests_fail_alone_in_native_batches() {
    let coord = native_coordinator(1);
    let mut rng = Rng::new(7);
    let good = coord
        .submit(Request::Classify {
            image: cifar::sample_class(2, &mut rng).image,
        })
        .unwrap();
    let bad = coord
        .submit(Request::Classify {
            image: Matrix::zeros(3, 5),
        })
        .unwrap();
    let bad_class = coord
        .submit(Request::Saliency {
            image: cifar::sample_class(0, &mut rng).image,
            class: 99,
        })
        .unwrap();
    let bad_table = coord
        .submit(Request::Shapley {
            n: 6,
            values: vec![0.0; 10],
            names: (0..6).map(|i| format!("f{i}")).collect(),
        })
        .unwrap();
    assert!(good.wait().is_ok());
    assert!(bad.wait().is_err());
    assert!(bad_class.wait().is_err());
    assert!(bad_table.wait().is_err());
    // the pipeline still serves afterwards
    let again = coord.call(Request::Classify {
        image: cifar::sample_class(1, &mut rng).image,
    });
    assert!(again.is_ok());
    coord.shutdown();
}

/// Native classification must actually classify the synthetic
/// distribution (the template model mirrors the AOT MicroCNN's task).
#[test]
fn native_classify_predicts_the_right_quadrant() {
    let coord = native_coordinator(1);
    let mut rng = Rng::new(3);
    for label in 0..4 {
        let s = cifar::sample_class(label, &mut rng);
        match coord.call(Request::Classify { image: s.image }).unwrap() {
            Response::Logits(l) => {
                assert_eq!(l.len(), 4);
                let pred = l
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                assert_eq!(pred, label);
            }
            other => panic!("unexpected response {other:?}"),
        }
    }
    coord.shutdown();
}

/// Shapley through the coordinator with a player count no compiled
/// variant ever covered (n=9): the native fused path has no such
/// constraint — odd sizes and odd batch remainders must work.
#[test]
fn odd_shapley_sizes_and_remainders_work() {
    let coord = native_coordinator(1);
    let oracle = NativeBackend::new();
    let mut rng = Rng::new(11);
    // batch cap for shapley is 8; submit 11 so a remainder batch forms
    let reqs: Vec<Request> = (0..11)
        .map(|_| Request::Shapley {
            n: 9,
            values: rng.gauss_vec(512),
            names: (0..9).map(|i| format!("f{i}")).collect(),
        })
        .collect();
    let pendings: Vec<_> = reqs
        .iter()
        .map(|r| coord.submit(r.clone()).unwrap())
        .collect();
    for (req, p) in reqs.iter().zip(pendings) {
        let got = p.wait().unwrap();
        let want = oracle.execute_single(req).unwrap();
        assert_responses_close(&got, &want, 1e-5);
    }
    coord.shutdown();
}

/// Auto mode in this artifact-less environment must fall back to the
/// native backend rather than failing startup.
#[test]
fn auto_backend_falls_back_to_native_offline() {
    let mut config = CoordinatorConfig::default();
    config.executors = 1;
    config.backend = BackendMode::Auto;
    config.artifact_dir = std::path::PathBuf::from("definitely-missing-artifacts");
    let coord = Coordinator::start(config).expect("auto mode must come up offline");
    let mut rng = Rng::new(5);
    let resp = coord.call(Request::Classify {
        image: cifar::sample_class(0, &mut rng).image,
    });
    assert!(resp.is_ok());
    coord.shutdown();
}
