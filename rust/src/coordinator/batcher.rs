//! Dynamic batcher — the paper's "parallel computation of multiple
//! inputs" (§III-E) as a serving-system component.
//!
//! Groups same-kind requests into batches, flushing on whichever comes
//! first: the kind's maximum batch size (matched to the compiled
//! artifact variants) or a deadline (`max_wait`).  Mixed-kind traffic
//! is split into per-kind batches in arrival order.

use crate::coordinator::request::{Envelope, RequestKind};
use crate::hwsim::DeviceKind;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// A batch ready for an executor.
#[derive(Debug)]
pub struct Batch {
    /// Request kind every envelope in the batch shares.
    pub kind: RequestKind,
    /// The batched envelopes in arrival order.
    pub envelopes: Vec<Envelope>,
    /// A cross-lane collective work item riding this (otherwise empty)
    /// batch — the member-stage transport of the collective plane.
    pub collective: Option<crate::coordinator::collective::CollectiveStage>,
    /// The analytic service-time prior (seconds) the placement layer
    /// priced this batch at on its chosen lane — the denominator of
    /// the executor's measured/predicted EWMA sample.  `0.0` until the
    /// batcher places the batch (and for collective stages, which are
    /// priced by the group planner instead).
    pub predicted_s: f64,
}

impl Batch {
    /// An ordinary batch of envelopes.
    pub fn new(kind: RequestKind, envelopes: Vec<Envelope>) -> Self {
        Self {
            kind,
            envelopes,
            collective: None,
            predicted_s: 0.0,
        }
    }

    /// A batch carrying one collective member stage and no envelopes
    /// (the stage's job owns the envelope).
    pub fn collective_stage(stage: crate::coordinator::collective::CollectiveStage) -> Self {
        Self {
            kind: RequestKind::Distill,
            envelopes: Vec::new(),
            collective: Some(stage),
            predicted_s: 0.0,
        }
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Per-kind maximum batch size.  Shapley packs 8 games into the
    /// `shapley_n*_b8` executable; classification packs 32 images into
    /// `cnn_fwd_b32`; per-request pipelines (distill/IG) still benefit
    /// from amortizing dispatch across the batch loop.
    pub max_batch: HashMap<RequestKind, usize>,
    /// Longest a request may wait for companions.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        let mut max_batch = HashMap::new();
        max_batch.insert(RequestKind::Classify, 32);
        max_batch.insert(RequestKind::Shapley, 8);
        max_batch.insert(RequestKind::Distill, 4);
        max_batch.insert(RequestKind::IntGrad, 4);
        max_batch.insert(RequestKind::Saliency, 8);
        Self {
            max_batch,
            max_wait: Duration::from_millis(2),
        }
    }
}

impl BatchPolicy {
    /// Maximum batch size for `kind` (1 when unconfigured).
    pub fn max_for(&self, kind: RequestKind) -> usize {
        *self.max_batch.get(&kind).unwrap_or(&1)
    }

    /// Placement-aware re-tuning: size each kind's batch to the sweet
    /// spot of the lane class that will win it
    /// ([`crate::coordinator::router::preferred_batch`]), never above
    /// the compiled-variant cap this policy already carries.  On the
    /// homogeneous TPU plane the fused kinds stay at (or within the
    /// sweet-spot tolerance of) their caps — deep batches amortize the
    /// dispatch and systolic fill/drain — while distillation drops to
    /// depth 1 on *every* lane class: its profile is priced once per
    /// member ([`crate::coordinator::router::profile_repeat`] scales
    /// with `b`), so companions buy no amortization and only add
    /// `max_wait` queueing delay.  CPU-won kinds stay shallow for the
    /// same reason — there is no per-op dispatch worth amortizing.
    pub fn tuned_for(&self, lanes: &[DeviceKind]) -> BatchPolicy {
        let mut tuned = self.clone();
        for (kind, cap) in self.max_batch.iter() {
            tuned.max_batch.insert(
                *kind,
                crate::coordinator::router::preferred_batch(*kind, lanes, *cap),
            );
        }
        tuned
    }
}

/// Accumulates envelopes and emits batches according to the policy.
#[derive(Debug)]
pub struct BatchAssembler {
    policy: BatchPolicy,
    pending: HashMap<RequestKind, Vec<Envelope>>,
    oldest: HashMap<RequestKind, Instant>,
}

impl BatchAssembler {
    /// An empty assembler under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            pending: HashMap::new(),
            oldest: HashMap::new(),
        }
    }

    /// Add an envelope; returns a full batch if the size trigger fired.
    pub fn offer(&mut self, env: Envelope) -> Option<Batch> {
        let kind = env.request.kind();
        let slot = self.pending.entry(kind).or_default();
        if slot.is_empty() {
            self.oldest.insert(kind, Instant::now());
        }
        slot.push(env);
        if slot.len() >= self.policy.max_for(kind) {
            return self.take(kind);
        }
        None
    }

    /// Flush any kind whose oldest member exceeded the deadline.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<RequestKind> = self
            .oldest
            .iter()
            .filter(|(k, t)| {
                now.duration_since(**t) >= self.policy.max_wait
                    && !self.pending.get(*k).map_or(true, |v| v.is_empty())
            })
            .map(|(k, _)| *k)
            .collect();
        expired.into_iter().filter_map(|k| self.take(k)).collect()
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let kinds: Vec<RequestKind> = self
            .pending
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(k, _)| *k)
            .collect();
        kinds.into_iter().filter_map(|k| self.take(k)).collect()
    }

    fn take(&mut self, kind: RequestKind) -> Option<Batch> {
        let envelopes = self.pending.remove(&kind)?;
        self.oldest.remove(&kind);
        if envelopes.is_empty() {
            return None;
        }
        Some(Batch::new(kind, envelopes))
    }

    /// Next deadline at which `flush_expired` could release work.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.oldest.values().min().map(|t| *t + self.policy.max_wait)
    }

    /// Requests currently waiting for companions.
    pub fn pending_count(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::linalg::matrix::Matrix;
    use std::sync::mpsc;

    fn env(id: u64, req: Request) -> Envelope {
        let (tx, _rx) = mpsc::channel();
        Envelope {
            id,
            request: req,
            reply: tx,
            enqueued_at: Instant::now(),
            deadline: None,
            tier: crate::xai::tiers::Tier::Exact,
            max_error: 0.0,
            degraded: false,
        }
    }

    fn classify(id: u64) -> Envelope {
        env(
            id,
            Request::Classify {
                image: Matrix::zeros(2, 2),
            },
        )
    }

    fn shapley(id: u64) -> Envelope {
        env(
            id,
            Request::Shapley {
                n: 3,
                values: vec![0.0; 8],
                names: vec!["a".into(), "b".into(), "c".into()],
            },
        )
    }

    fn policy(classify_max: usize) -> BatchPolicy {
        let mut p = BatchPolicy::default();
        p.max_batch.insert(RequestKind::Classify, classify_max);
        p
    }

    #[test]
    fn size_trigger_fires() {
        let mut a = BatchAssembler::new(policy(3));
        assert!(a.offer(classify(1)).is_none());
        assert!(a.offer(classify(2)).is_none());
        let b = a.offer(classify(3)).expect("batch at size 3");
        assert_eq!(b.envelopes.len(), 3);
        assert_eq!(b.kind, RequestKind::Classify);
        assert_eq!(a.pending_count(), 0);
    }

    #[test]
    fn kinds_do_not_mix() {
        let mut a = BatchAssembler::new(BatchPolicy::default());
        a.offer(classify(1));
        a.offer(shapley(2));
        a.offer(classify(3));
        let batches = a.flush_all();
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert!(b
                .envelopes
                .iter()
                .all(|e| e.request.kind() == b.kind));
        }
    }

    #[test]
    fn deadline_trigger_fires() {
        let mut p = BatchPolicy::default();
        p.max_wait = Duration::from_millis(0);
        let mut a = BatchAssembler::new(p);
        a.offer(classify(1));
        let batches = a.flush_expired(Instant::now() + Duration::from_millis(1));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].envelopes.len(), 1);
    }

    #[test]
    fn not_expired_not_flushed() {
        let mut p = BatchPolicy::default();
        p.max_wait = Duration::from_secs(60);
        let mut a = BatchAssembler::new(p);
        a.offer(classify(1));
        assert!(a.flush_expired(Instant::now()).is_empty());
        assert_eq!(a.pending_count(), 1);
    }

    #[test]
    fn arrival_order_preserved() {
        let mut a = BatchAssembler::new(policy(10));
        for i in 0..5 {
            a.offer(classify(i));
        }
        let b = a.flush_all().pop().unwrap();
        let ids: Vec<u64> = b.envelopes.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut a = BatchAssembler::new(BatchPolicy::default());
        assert!(a.next_deadline().is_none());
        a.offer(classify(1));
        assert!(a.next_deadline().is_some());
    }
}
