"""Vandermonde-matrix construction kernel — IG interpolation (§III-C).

The paper accelerates integrated gradients by fitting an interpolating
polynomial through sampled values of F along the integration path; the
interpolation system is a Vandermonde matrix V[i, j] = x_i^j, solved on
the accelerator.

Building V is an outer-power pattern: each VMEM tile computes
x_i^(j0..j0+bn) with a per-tile exponent offset.  We evaluate powers via
exp(j * log|x|) with sign tracking — a fully vectorized VPU pattern —
rather than a sequential cumulative product, so the kernel has no
loop-carried dependency and tiles are independent (the property the
paper's data decomposition relies on).

The *solve* V a = y happens in the L2 graph (jnp.linalg.solve lowers to
LU on all PJRT backends); on a real TPU the triangular solves run on the
VPU while the factorization's rank-k updates hit the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dft_matmul import TILE


def _vandermonde_kernel(x_ref, o_ref, *, bn: int):
    j0 = pl.program_id(1) * bn
    x = x_ref[...]                       # (bm, 1) tile of sample points
    exps = (j0 + jax.lax.iota(jnp.float32, bn))[None, :]   # (1, bn)
    ax = jnp.abs(x)
    # x^j = sign_factor * exp(j * log|x|);  0^0 = 1, 0^j = 0 handled below.
    logax = jnp.log(jnp.where(ax > 0, ax, 1.0))
    mag = jnp.exp(exps * logax)
    # sign: negative base flips sign on odd exponents.
    odd = jnp.mod(exps, 2.0)
    sign = jnp.where(x < 0, 1.0 - 2.0 * odd, 1.0)
    zero_base = ax == 0.0
    zero_exp = exps == 0.0
    val = jnp.where(zero_base, jnp.where(zero_exp, 1.0, 0.0), sign * mag)
    o_ref[...] = val


@functools.partial(jax.jit, static_argnames=("n", "tile"))
def vandermonde_build_pallas(xs: jnp.ndarray, n: int | None = None,
                             tile: int = TILE) -> jnp.ndarray:
    """Build the m x n Vandermonde matrix V[i, j] = xs[i]**j.

    ``n`` defaults to len(xs) (square system).  Tiles are (tile, tile)
    blocks; the row tile streams the sample points, the column index is
    reconstructed from the grid position.
    """
    m = xs.shape[0]
    if n is None:
        n = m
    bm, bn = min(tile, m), min(tile, n)
    pm = (-m) % bm
    xcol = jnp.pad(xs.astype(jnp.float32), (0, pm))[:, None]
    gm = xcol.shape[0] // bm
    gn = (n + bn - 1) // bn
    out = pl.pallas_call(
        functools.partial(_vandermonde_kernel, bn=bn),
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((bm, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=True,
    )(xcol)
    return out[:m, :n]
