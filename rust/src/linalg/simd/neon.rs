//! NEON kernels (aarch64).
//!
//! NEON is baseline on aarch64, so these are unconditionally
//! executable there; the functions are still `unsafe` and
//! `#[target_feature(enable = "neon")]` to keep the calling contract
//! identical to the AVX2 level (the dispatch table is the only
//! caller).  A `float32x4_t` holds 4 f32 lanes = 2 interleaved
//! complex values; the re/im swap inside each complex is a single
//! `vrev64q_f32`, and sign-flips are XORs on the bit pattern.
//!
//! [`radix4_kickoff`] has no NEON specialization (a whole radix-4
//! block spans two registers and the shuffles dominate at 128-bit
//! width); the dispatch table routes it to the scalar kernel, which
//! is the semantic source of truth anyway.

#![allow(unsafe_op_in_unsafe_fn)]

use crate::linalg::complex::C32;
use std::arch::aarch64::*;

/// View a `C32` slice as its interleaved f32 storage.
fn as_f32(buf: &[C32]) -> &[f32] {
    // SAFETY: C32 is #[repr(C)] { re: f32, im: f32 } — a [C32] of
    // length n is exactly 2n contiguous aligned f32s, no padding.
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const f32, buf.len() * 2) }
}

/// Mutable interleaved f32 view of a `C32` slice.
fn as_f32_mut(buf: &mut [C32]) -> &mut [f32] {
    // SAFETY: as for `as_f32`; the &mut borrow is exclusive.
    unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut f32, buf.len() * 2) }
}

/// `out += a · b`: 4-wide FMA over B rows with broadcast A scalars,
/// scalar tail for `n % 4` columns.
///
/// # Safety
/// Requires NEON (baseline on aarch64).  Slice shape relations
/// (`a.len() == m·k` etc.) are asserted by the dispatch wrapper and
/// bound every index below.
#[target_feature(enable = "neon")]
pub unsafe fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    let quads = n / 4 * 4;
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut j = 0;
            while j < quads {
                let bv = vld1q_f32(brow.as_ptr().add(j));
                let ov = vld1q_f32(orow.as_ptr().add(j));
                vst1q_f32(orow.as_mut_ptr().add(j), vfmaq_n_f32(ov, bv, av));
                j += 4;
            }
            for j in quads..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Complex `out += a · b` over interleaved storage: per (i, k) the A
/// scalar is broadcast and multiplied against 2-complex B vectors.
///
/// # Safety
/// Requires NEON; shape relations asserted by the dispatch wrapper.
#[target_feature(enable = "neon")]
pub unsafe fn gemm_c32(m: usize, k: usize, n: usize, a: &[C32], b: &[C32], out: &mut [C32]) {
    let pairs = n / 2 * 2;
    let bf = as_f32(b);
    let of = as_f32_mut(out);
    // sign mask negating the even (re) lanes: the −ai·bi term
    let neg_even = vld1q_u32([0x8000_0000u32, 0, 0x8000_0000, 0].as_ptr());
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            let mut j = 0;
            while j < pairs {
                let vb = vld1q_f32(bf.as_ptr().add((kk * n + j) * 2));
                let vo = vld1q_f32(of.as_ptr().add((i * n + j) * 2));
                // [bi, br, …] for the cross terms
                let vb_swap = vrev64q_f32(vb);
                // even: ar·br − ai·bi ; odd: ar·bi + ai·br
                let cross = veorq_u32(
                    vreinterpretq_u32_f32(vmulq_n_f32(vb_swap, av.im)),
                    neg_even,
                );
                let t = vfmaq_n_f32(vreinterpretq_f32_u32(cross), vb, av.re);
                vst1q_f32(of.as_mut_ptr().add((i * n + j) * 2), vaddq_f32(vo, t));
                j += 2;
            }
            if j < n {
                // odd trailing column: scalar complex FMA on the view
                let bi = (kk * n + j) * 2;
                let oi = (i * n + j) * 2;
                let (br, bim) = (bf[bi], bf[bi + 1]);
                of[oi] += av.re * br - av.im * bim;
                of[oi + 1] += av.re * bim + av.im * br;
            }
        }
    }
}

/// One radix-2 butterfly stage (span `len`) with 2 butterflies per
/// iteration; delegates to the scalar stage when `len/2 < 2`.
///
/// # Safety
/// Requires NEON.  `buf.len() % len == 0` and `panel.len() == len/2`
/// (debug-asserted by the dispatch wrapper) bound every index.
#[target_feature(enable = "neon")]
pub unsafe fn butterfly_stage(buf: &mut [C32], len: usize, panel: &[C32], inverse: bool) {
    let half = len / 2;
    if half < 2 {
        return super::scalar::butterfly_stage(buf, len, panel, inverse);
    }
    // flip the odd (im) lanes of w for the inverse conjugation
    let conj_mask = if inverse {
        vld1q_u32([0u32, 0x8000_0000, 0, 0x8000_0000].as_ptr())
    } else {
        vdupq_n_u32(0)
    };
    let neg_even = vld1q_u32([0x8000_0000u32, 0, 0x8000_0000, 0].as_ptr());
    let n = buf.len();
    let bf = as_f32_mut(buf);
    let pf = as_f32(panel);
    let mut j = 0;
    while j < n {
        let mut kq = 0;
        // 2 butterflies (one q-register of complex) per step; half is
        // a power of two ≥ 2, so there is no remainder.
        while kq < half {
            let ui = (j + kq) * 2;
            let vi = (j + kq + half) * 2;
            let u = vld1q_f32(bf.as_ptr().add(ui));
            let v = vld1q_f32(bf.as_ptr().add(vi));
            let w = vreinterpretq_f32_u32(veorq_u32(
                vreinterpretq_u32_f32(vld1q_f32(pf.as_ptr().add(kq * 2))),
                conj_mask,
            ));
            // w_re = [wr, wr, …], w_im = [wi, wi, …]: trn with itself
            // duplicates the even / odd lanes
            let w_re = vtrn1q_f32(w, w);
            let w_im = vtrn2q_f32(w, w);
            let v_swap = vrev64q_f32(v);
            // t = v·w: even vr·wr − vi·wi, odd vi·wr + vr·wi
            let cross = vreinterpretq_f32_u32(veorq_u32(
                vreinterpretq_u32_f32(vmulq_f32(v_swap, w_im)),
                neg_even,
            ));
            let t = vfmaq_f32(cross, v, w_re);
            vst1q_f32(bf.as_mut_ptr().add(ui), vaddq_f32(u, t));
            vst1q_f32(bf.as_mut_ptr().add(vi), vsubq_f32(u, t));
            kq += 2;
        }
        j += len;
    }
}

/// `acc[i] = (acc[i] · other[i]) · scale`, 2 complex per iteration
/// with a scalar tail.
///
/// # Safety
/// Requires NEON; `acc.len() == other.len()` (asserted by the
/// dispatch wrapper) bounds all indices.
#[target_feature(enable = "neon")]
pub unsafe fn cmul_scale_slice(acc: &mut [C32], other: &[C32], scale: f32) {
    let n = acc.len();
    let pairs = n / 2 * 2;
    let neg_even = vld1q_u32([0x8000_0000u32, 0, 0x8000_0000, 0].as_ptr());
    {
        let af = as_f32_mut(acc);
        let of = as_f32(other);
        let mut i = 0;
        while i < pairs {
            let va = vld1q_f32(af.as_ptr().add(i * 2));
            let vb = vld1q_f32(of.as_ptr().add(i * 2));
            let vb_re = vtrn1q_f32(vb, vb);
            let vb_im = vtrn2q_f32(vb, vb);
            let va_swap = vrev64q_f32(va);
            let cross = vreinterpretq_f32_u32(veorq_u32(
                vreinterpretq_u32_f32(vmulq_f32(va_swap, vb_im)),
                neg_even,
            ));
            let prod = vfmaq_f32(cross, va, vb_re);
            vst1q_f32(af.as_mut_ptr().add(i * 2), vmulq_n_f32(prod, scale));
            i += 2;
        }
    }
    for i in pairs..n {
        acc[i] = (acc[i] * other[i]).scale(scale);
    }
}
