//! Deterministic simulated network transport with fault injection.
//!
//! [`SimNet`] implements [`Transport`] over an in-memory link that
//! behaves like a real one: every frame pays a serialization delay
//! (`bytes / bandwidth`, back-to-back frames queue behind each other on
//! the link), a propagation latency, and a seeded uniform jitter; the
//! fault injector can **drop** frames, **duplicate** them, or
//! **partition** the link entirely.  All randomness comes from one
//! seeded [`Rng`] per direction, so a given send sequence makes the
//! same drop/duplicate/jitter decisions on every run — network tests
//! are reproducible, not flaky.
//!
//! Failure semantics (mirrored in `docs/ARCHITECTURE.md` §6):
//!
//! * a **dropped** frame is lost silently — `send` still returns `Ok`,
//!   exactly like a real NIC;
//! * a **duplicated** frame is delivered twice, in order — receivers
//!   must be idempotent (the host plane's per-job state makes them so);
//! * a **partitioned** link delivers nothing in either direction;
//!   frames already in flight are *held*, not dropped, and flow again
//!   if the partition heals — the worst case for timeout logic;
//! * frames are never reordered within a direction, and never
//!   corrupted — corruption is the wire checksum's department, and is
//!   tested there by flipping bits explicitly.

use crate::hwsim::pool::Interconnect;
use crate::transport::{Recv, SendError, Transport};
use crate::util::rng::Rng;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Behavior of one simulated link (both directions share it).
#[derive(Debug, Clone)]
pub struct LinkConfig {
    /// Link bandwidth (bytes/s); each frame occupies the link for
    /// `len / bandwidth` before it propagates.
    pub bandwidth_bytes_per_s: f64,
    /// One-way propagation latency added to every frame.
    pub latency: Duration,
    /// Per-frame jitter, uniform in `[0, jitter)`, added to latency.
    pub jitter: Duration,
    /// Probability a frame is silently lost.
    pub drop_rate: f64,
    /// Probability a frame is delivered twice.
    pub duplicate_rate: f64,
    /// Seed of the per-direction fault/jitter RNGs.
    pub seed: u64,
}

impl LinkConfig {
    /// A perfect link: infinite bandwidth, zero latency, no faults.
    pub fn ideal(seed: u64) -> LinkConfig {
        LinkConfig {
            bandwidth_bytes_per_s: f64::INFINITY,
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            drop_rate: 0.0,
            duplicate_rate: 0.0,
            seed,
        }
    }

    /// Datacenter Ethernet-class figures (25 GbE through a kernel
    /// stack): ~3.1 GB/s, 30 µs one-way, a little jitter.  Matches the
    /// pricing constants of
    /// [`crate::hwsim::pool::Interconnect::ethernet`].
    pub fn ethernet(seed: u64) -> LinkConfig {
        LinkConfig {
            bandwidth_bytes_per_s: 3.125e9,
            latency: Duration::from_micros(30),
            jitter: Duration::from_micros(5),
            drop_rate: 0.0,
            duplicate_rate: 0.0,
            seed,
        }
    }

    /// RDMA-class figures (100 Gb/s fabric, kernel-bypass): 12.5 GB/s,
    /// 2 µs one-way, negligible jitter.  Matches
    /// [`crate::hwsim::pool::Interconnect::rdma`].
    pub fn rdma(seed: u64) -> LinkConfig {
        LinkConfig {
            bandwidth_bytes_per_s: 12.5e9,
            latency: Duration::from_micros(2),
            jitter: Duration::ZERO,
            drop_rate: 0.0,
            duplicate_rate: 0.0,
            seed,
        }
    }

    /// The [`Interconnect`] pricing class of this link for the hwsim
    /// cost model: bandwidth and one-way latency carry over directly;
    /// the per-byte serialization term comes from the named class the
    /// figures belong to — links at RDMA-fabric bandwidth or better
    /// are assumed kernel-bypass
    /// ([`crate::hwsim::pool::Interconnect::rdma`]), slower finite
    /// links pay the Ethernet-class software-stack marshalling
    /// ([`crate::hwsim::pool::Interconnect::ethernet`]), and an
    /// infinite-bandwidth (ideal) link serializes for free.
    pub fn interconnect(&self) -> Interconnect {
        let rdma = Interconnect::rdma();
        let ser_s_per_byte = if self.bandwidth_bytes_per_s.is_infinite() {
            0.0
        } else if self.bandwidth_bytes_per_s >= rdma.link_bw {
            rdma.ser_s_per_byte
        } else {
            Interconnect::ethernet().ser_s_per_byte
        };
        Interconnect {
            link_bw: self.bandwidth_bytes_per_s,
            hop_latency_s: self.latency.as_secs_f64(),
            ser_s_per_byte,
        }
    }
}

/// A frame scheduled for delivery at a virtual-clock instant.
struct Delivery {
    at: Instant,
    seq: u64,
    frame: Vec<u8>,
}

// BinaryHeap is a max-heap; order Deliveries inverted so the earliest
// (at, seq) pops first.
impl PartialEq for Delivery {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Delivery {}
impl PartialOrd for Delivery {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Delivery {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// One direction of the link: pending deliveries + its own fault RNG.
struct Dir {
    state: Mutex<DirState>,
    arrived: Condvar,
}

struct DirState {
    heap: BinaryHeap<Delivery>,
    /// The link is occupied transmitting until this instant
    /// (bandwidth serialization: back-to-back frames queue).
    busy_until: Instant,
    /// Monotonic sequence, tie-breaks equal delivery instants.
    seq: u64,
    rng: Rng,
    closed: bool,
}

struct Link {
    cfg: LinkConfig,
    partitioned: AtomicBool,
    dirs: [Dir; 2],
}

/// One endpoint of a simulated network link.  Build a connected pair
/// with [`SimNet::pair`]; inject a partition with
/// [`SimNet::partition`].
pub struct SimNet {
    link: Arc<Link>,
    /// This endpoint transmits into `dirs[side]` and receives from
    /// `dirs[1 - side]`.
    side: usize,
}

impl SimNet {
    /// A connected endpoint pair over one link with the given behavior.
    /// The two directions get independent RNG streams derived from
    /// `cfg.seed`, so either side's fault schedule is reproducible.
    pub fn pair(cfg: LinkConfig) -> (SimNet, SimNet) {
        let now = Instant::now();
        let dir = |seed: u64| Dir {
            state: Mutex::new(DirState {
                heap: BinaryHeap::new(),
                busy_until: now,
                seq: 0,
                rng: Rng::new(seed),
                closed: false,
            }),
            arrived: Condvar::new(),
        };
        let link = Arc::new(Link {
            dirs: [dir(cfg.seed), dir(cfg.seed ^ 0x9E37_79B9_7F4A_7C15)],
            partitioned: AtomicBool::new(false),
            cfg,
        });
        (
            SimNet {
                link: link.clone(),
                side: 0,
            },
            SimNet { link, side: 1 },
        )
    }

    /// Partition or heal the link (both directions).  While
    /// partitioned nothing is delivered; in-flight frames are held and
    /// resume on heal.
    pub fn partition(&self, sealed: bool) {
        self.link.partitioned.store(sealed, Ordering::SeqCst);
        if !sealed {
            for d in &self.link.dirs {
                d.arrived.notify_all();
            }
        }
    }

    /// Whether the link is currently partitioned.
    pub fn is_partitioned(&self) -> bool {
        self.link.partitioned.load(Ordering::SeqCst)
    }

    /// Close both directions (peers see [`Recv::Closed`] once drained).
    pub fn close(&self) {
        for d in &self.link.dirs {
            let mut s = d.state.lock().unwrap();
            s.closed = true;
            drop(s);
            d.arrived.notify_all();
        }
    }
}

impl Transport for SimNet {
    fn send(&self, frame: Vec<u8>) -> Result<(), SendError> {
        let cfg = &self.link.cfg;
        let dir = &self.link.dirs[self.side];
        let mut s = dir.state.lock().unwrap();
        if s.closed {
            return Err(SendError::Closed);
        }
        // Fault schedule: one uniform draw per decision, in a fixed
        // order, so a send sequence replays identically for a seed.
        let dropped = cfg.drop_rate > 0.0 && s.rng.uniform() < cfg.drop_rate;
        let duplicated = cfg.duplicate_rate > 0.0 && s.rng.uniform() < cfg.duplicate_rate;
        let jitter = if cfg.jitter.is_zero() {
            Duration::ZERO
        } else {
            cfg.jitter.mul_f64(s.rng.uniform())
        };
        if dropped {
            // silently lost: the sender cannot tell (like a real NIC)
            return Ok(());
        }
        let now = Instant::now();
        let xmit = if cfg.bandwidth_bytes_per_s.is_finite() {
            Duration::from_secs_f64(frame.len() as f64 / cfg.bandwidth_bytes_per_s)
        } else {
            Duration::ZERO
        };
        // bandwidth serialization: this frame occupies the link after
        // whatever is already transmitting
        let start = s.busy_until.max(now);
        s.busy_until = start + xmit;
        let at = s.busy_until + cfg.latency + jitter;
        let seq = s.seq;
        s.seq += if duplicated { 2 } else { 1 };
        if duplicated {
            s.heap.push(Delivery {
                at,
                seq: seq + 1,
                frame: frame.clone(),
            });
        }
        s.heap.push(Delivery { at, seq, frame });
        drop(s);
        dir.arrived.notify_all();
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Recv {
        let deadline = Instant::now() + timeout;
        let dir = &self.link.dirs[1 - self.side];
        let mut s = dir.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let partitioned = self.link.partitioned.load(Ordering::SeqCst);
            if s.closed && (s.heap.is_empty() || partitioned) {
                // held frames on a closed, partitioned link never land
                return Recv::Closed;
            }
            let next_at = if partitioned {
                None // deliveries are held while partitioned
            } else {
                s.heap.peek().map(|d| d.at)
            };
            if let Some(at) = next_at {
                if at <= now {
                    let d = s.heap.pop().expect("peeked above");
                    return Recv::Frame(d.frame);
                }
            }
            if now >= deadline {
                return Recv::Timeout;
            }
            // sleep until the earliest of: delivery due, caller deadline
            let until = next_at.map_or(deadline, |at| at.min(deadline));
            let (g, _) = dir
                .arrived
                .wait_timeout(s, until.saturating_duration_since(now))
                .unwrap();
            s = g;
        }
    }

    fn close(&self) {
        SimNet::close(self);
    }
}

impl Drop for SimNet {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames_until_timeout(ep: &SimNet) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Recv::Frame(f) = ep.recv_timeout(Duration::from_millis(50)) {
            out.push(f);
        }
        out
    }

    #[test]
    fn ideal_link_delivers_in_order() {
        let (a, b) = SimNet::pair(LinkConfig::ideal(1));
        for i in 0..4u8 {
            a.send(vec![i]).unwrap();
        }
        assert_eq!(
            frames_until_timeout(&b),
            vec![vec![0], vec![1], vec![2], vec![3]]
        );
    }

    #[test]
    fn latency_and_bandwidth_delay_delivery() {
        // 10 kB at 1 MB/s = 10 ms serialization + 5 ms latency
        let cfg = LinkConfig {
            bandwidth_bytes_per_s: 1.0e6,
            latency: Duration::from_millis(5),
            ..LinkConfig::ideal(2)
        };
        let (a, b) = SimNet::pair(cfg);
        let t0 = Instant::now();
        a.send(vec![0u8; 10_000]).unwrap();
        let Recv::Frame(f) = b.recv_timeout(Duration::from_secs(2)) else {
            panic!("frame lost");
        };
        assert_eq!(f.len(), 10_000);
        assert!(
            t0.elapsed() >= Duration::from_millis(14),
            "arrived after {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn drops_are_silent_and_deterministic() {
        let cfg = LinkConfig {
            drop_rate: 0.5,
            ..LinkConfig::ideal(3)
        };
        let run = || {
            let (a, b) = SimNet::pair(cfg.clone());
            for i in 0..32u8 {
                a.send(vec![i]).unwrap(); // Ok even when dropped
            }
            frames_until_timeout(&b)
        };
        let first = run();
        assert!(!first.is_empty() && first.len() < 32, "got {}", first.len());
        // seeded: the same sequence drops the same frames
        assert_eq!(first, run());
    }

    #[test]
    fn duplicates_deliver_twice_in_order() {
        let cfg = LinkConfig {
            duplicate_rate: 1.0,
            ..LinkConfig::ideal(4)
        };
        let (a, b) = SimNet::pair(cfg);
        a.send(vec![7]).unwrap();
        a.send(vec![8]).unwrap();
        assert_eq!(
            frames_until_timeout(&b),
            vec![vec![7], vec![7], vec![8], vec![8]]
        );
    }

    #[test]
    fn partition_holds_frames_until_heal() {
        let (a, b) = SimNet::pair(LinkConfig::ideal(5));
        a.partition(true);
        a.send(vec![1]).unwrap();
        assert_eq!(b.recv_timeout(Duration::from_millis(20)), Recv::Timeout);
        a.partition(false);
        assert_eq!(
            b.recv_timeout(Duration::from_secs(1)),
            Recv::Frame(vec![1])
        );
    }

    #[test]
    fn closed_link_reports_closed() {
        let (a, b) = SimNet::pair(LinkConfig::ideal(6));
        drop(a);
        assert_eq!(b.recv_timeout(Duration::from_millis(5)), Recv::Closed);
        assert_eq!(b.send(vec![1]), Err(SendError::Closed));
    }
}
