//! Fig. 10 — scalability of the three acceleration methods with matrix
//! size (distillation solve, sizes 16 … 1024), plus the p-core sweep:
//! the same 1024² solve sharded across a simulated TPU [`DevicePool`]
//! with a priced interconnect (Algorithm 1 end-to-end).
//!
//! Two series per device: the *simulated* device time (the paper's
//! figure) and — at every size, now that the plan-based FFT engine
//! makes 1024² tractable — the *measured* native Rust wallclock of
//! the same algorithm, grounding the simulation in real execution.
//! Paper shape: all curves grow with size; TPU >30x faster than CPU at
//! 1024²; near-linear (sub-linear only from merge traffic) scaling
//! with p thanks to data decomposition.
//!
//! The `sim_sharded_tpu_p{1,2,4,8}_1024` rows are deterministic and
//! tracked by the CI regression gate (`xai-accel bench-check`).

use std::time::Instant;
use xai_accel::bench::{json, BenchResult};
use xai_accel::hwsim::{self, DeviceKind, DevicePool};
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::trace::NativeEngine;
use xai_accel::util::rng::Rng;
use xai_accel::util::table::{fmt_time, Table};
use xai_accel::xai::{distillation, workloads};

fn main() {
    let quick = xai_accel::bench::quick_requested();
    let sizes: &[usize] = if quick {
        &[16, 64, 256, 1024]
    } else {
        &[16, 32, 64, 128, 256, 512, 1024]
    };

    let mut table = Table::new("Fig. 10: distillation-solve time vs matrix size")
        .header(&[
            "size", "CPU(sim)", "GPU(sim)", "TPU(sim)", "TPU speedup", "native Rust (measured)",
        ]);
    let mut csv = String::from("size,cpu_s,gpu_s,tpu_s,native_s\n");
    let mut rng = Rng::new(5);

    for &n in sizes {
        let fft = workloads::distill_solve_trace_sched(n, workloads::Schedule::FftForm);
        let mm = workloads::distill_solve_trace_sched(n, workloads::Schedule::MatmulForm);
        let t: Vec<f64> = DeviceKind::all()
            .iter()
            .map(|&k| {
                let trace = if k == DeviceKind::Cpu { &fft } else { &mm };
                hwsim::device_for(k).replay(trace).time_s
            })
            .collect();

        // ground truth: measure the real algorithm natively (FFT form —
        // what this host actually runs fastest).  The plan-based engine
        // made every size tractable: building `y` warms the plan cache,
        // so the timed solve reflects steady-state serving cost.
        let native = {
            let x = Matrix::from_fn(n, n, |_, _| 3.0 + rng.gauss_f32());
            let y = circ_conv2(&x, &Matrix::identity_kernel(n, n));
            let mut eng = NativeEngine::new_fft_baseline();
            let t0 = Instant::now();
            let k = distillation::distill_fft(&mut eng, &x, &y, 1e-6);
            let dt = t0.elapsed().as_secs_f64();
            assert!(k.is_finite());
            dt
        };

        table.row(&[
            format!("{n}x{n}"),
            fmt_time(t[0]),
            fmt_time(t[1]),
            fmt_time(t[2]),
            format!("{:.1}x", t[0] / t[2]),
            fmt_time(native),
        ]);
        csv.push_str(&format!("{n},{},{},{},{native}\n", t[0], t[1], t[2]));
    }
    table.print();
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig10.csv", csv).ok();
    println!("paper shape: monotone growth; TPU >30x over CPU at 1024x1024");

    // ---- p-core sweep: Algorithm-1 sharded solve on a TPU pool ------
    // The distill solve at 1024², sharded across p single-core TPU
    // chips with an explicitly priced ICI (ring merges + scatter), in
    // quick mode too — these rows are the Fig. 10 scaling claim made
    // reproducible, and the CI gate tracks them.
    let n = 1024usize;
    let mut sweep = Table::new("Fig. 10 p-core sweep: sharded 1024² solve on a TPU DevicePool")
        .header(&["p", "time", "speedup", "compute", "collective"]);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut times = std::collections::HashMap::new();
    for p in [1usize, 2, 4, 8] {
        let pool = DevicePool::homogeneous(DeviceKind::Tpu, p);
        let rep = pool.replay_sharded(&workloads::distill_solve_trace_sharded(n, p));
        times.insert(p, rep.time_s);
        sweep.row(&[
            format!("{p}"),
            fmt_time(rep.time_s),
            format!("{:.1}x", times[&1] / rep.time_s),
            fmt_time(rep.compute_s),
            fmt_time(rep.collective_s),
        ]);
        // deterministic, machine-independent: tracked by bench-check
        results.push(BenchResult::point(
            &format!("sim_sharded_tpu_p{p}_1024"),
            rep.time_s,
        ));
    }
    sweep.print();
    let speedup = times[&1] / times[&8];
    let sweep_ok = speedup >= 3.0 && speedup < 8.0;
    println!(
        "acceptance (p=8 at least 3x over p=1, sub-linear from priced interconnect): {} ({speedup:.1}x)",
        if sweep_ok { "PASS" } else { "FAIL" }
    );
    let refs: Vec<&BenchResult> = results.iter().collect();
    json::emit(&refs);

    // BENCH_ENFORCE=1 turns the printed acceptance verdict into an
    // exit code so a driver can hard-gate the scaling claim.
    let enforce = std::env::var("BENCH_ENFORCE")
        .map(|v| v == "1" || v == "true")
        .unwrap_or(false);
    if enforce && !sweep_ok {
        eprintln!("acceptance FAILED: sharded sweep speedup {speedup:.2}x (need >= 3x, sub-linear)");
        std::process::exit(1);
    }
}
