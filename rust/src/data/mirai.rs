//! Synthetic MIRAI-style malware trace tables (Fig. 12).
//!
//! The paper's detector consumes register-trace tables: each row a
//! register, each column a clock cycle of hex values, with one column
//! corresponding to the `ATTACK_VECTOR` assignment that distillation
//! must surface as the dominant feature.  We generate tables with a
//! planted attack column: registers are correlated noise except at the
//! attack cycle, where a coordinated multi-register signature appears
//! (mode flag written, bot state fan-out) — checkable ground truth.

use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Registers traced (rows). Matches the Fig. 12 snapshot scale.
pub const REGISTERS: usize = 16;
/// Clock cycles captured (cols).
pub const CYCLES: usize = 16;

/// A trace table with its planted ground truth.
#[derive(Debug, Clone)]
pub struct TraceTable {
    /// Register values normalized to [0, 1] (hex / 0xFF).
    pub table: Matrix,
    /// The planted ATTACK_VECTOR clock-cycle column (None for benign).
    pub attack_cycle: Option<usize>,
}

/// Benign trace: smooth correlated register activity.
pub fn benign_trace(rng: &mut Rng) -> TraceTable {
    let mut table = Matrix::zeros(REGISTERS, CYCLES);
    for r in 0..REGISTERS {
        let mut v = rng.uniform() as f32;
        for c in 0..CYCLES {
            // slow random walk per register (clamped)
            v = (v + 0.1 * rng.gauss_f32()).clamp(0.0, 1.0);
            table.set(r, c, v * 0.5 + 0.1);
        }
    }
    TraceTable {
        table,
        attack_cycle: None,
    }
}

/// Malware trace: benign background + a coordinated write burst at the
/// planted attack cycle (the ATTACK_VECTOR assignment fan-out).
pub fn malware_trace(attack_cycle: usize, rng: &mut Rng) -> TraceTable {
    assert!(attack_cycle < CYCLES);
    let mut t = benign_trace(rng);
    for r in 0..REGISTERS {
        // most registers spike coherently at the attack cycle
        if rng.uniform() < 0.75 {
            t.table.set(r, attack_cycle, 0.9 + 0.1 * rng.uniform() as f32);
        }
    }
    t.attack_cycle = Some(attack_cycle);
    t
}

/// A labeled corpus of traces for detector-style experiments.
pub fn corpus(n: usize, rng: &mut Rng) -> Vec<(TraceTable, bool)> {
    (0..n)
        .map(|_| {
            if rng.uniform() < 0.5 {
                let cyc = rng.below(CYCLES as u64) as usize;
                (malware_trace(cyc, rng), true)
            } else {
                (benign_trace(rng), false)
            }
        })
        .collect()
}

/// Column-energy heuristic: cycles ranked by deviation from the table
/// mean (a cheap detector the distillation explanation is checked
/// against in tests).
pub fn column_energies(t: &TraceTable) -> Vec<f32> {
    let mean: f32 =
        t.table.data.iter().sum::<f32>() / (t.table.rows * t.table.cols) as f32;
    (0..t.table.cols)
        .map(|c| {
            (0..t.table.rows)
                .map(|r| {
                    let d = t.table.get(r, c) - mean;
                    d * d
                })
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn malware_attack_column_has_peak_energy() {
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let cyc = rng.below(CYCLES as u64) as usize;
            let t = malware_trace(cyc, &mut rng);
            let e = column_energies(&t);
            let argmax = e
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(argmax, cyc, "energies {e:?}");
        }
    }

    #[test]
    fn benign_has_no_ground_truth() {
        let mut rng = Rng::new(1);
        assert!(benign_trace(&mut rng).attack_cycle.is_none());
    }

    #[test]
    fn values_are_normalized() {
        let mut rng = Rng::new(2);
        let t = malware_trace(5, &mut rng);
        assert!(t.table.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn corpus_is_balancedish() {
        let mut rng = Rng::new(3);
        let c = corpus(200, &mut rng);
        let malware = c.iter().filter(|(_, m)| *m).count();
        assert!(malware > 60 && malware < 140);
    }
}
