//! Ablation — the paper's core algorithmic claim: one spectral solve
//! (Eq. 5) vs iterative gradient-descent distillation.
//!
//! Measures *real native wallclock* (not simulation) of both solvers at
//! several sizes, plus solution quality against a planted kernel, plus
//! recorded-FLOP ratios.  The FFT solve must be orders of magnitude
//! cheaper at equal (or better) recovery error.

use std::time::Instant;
use xai_accel::bench::runner_from_args;
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::trace::NativeEngine;
use xai_accel::util::rng::Rng;
use xai_accel::util::table::{fmt_time, Table};
use xai_accel::xai::distillation;

fn main() {
    let runner = runner_from_args();
    let mut rng = Rng::new(0);
    let mut table = Table::new("ablation: spectral solve (Eq. 5) vs gradient descent")
        .header(&[
            "size", "solver", "wallclock", "recovery err", "recorded GFLOP",
        ]);

    for n in [16usize, 32, 64] {
        let x = Matrix::from_fn(n, n, |_, _| 4.0 + rng.gauss_f32());
        let mut k_true = Matrix::zeros(n, n);
        k_true.set(0, 0, 0.7);
        k_true.set(0, 1, 0.2);
        k_true.set(1, 0, 0.1);
        let y = circ_conv2(&x, &k_true);

        // spectral solve
        let mut eng = NativeEngine::new_fft_baseline();
        let mut k_fft = Matrix::zeros(n, n);
        let r = runner.run("fft", || {
            k_fft = distillation::distill_fft(&mut eng, &x, &y, 1e-9);
        });
        let fft_flops = eng.take_trace().total_flops() as f64 / r.iters as f64;
        table.row(&[
            format!("{n}x{n}"),
            "spectral (Eq.5)".into(),
            fmt_time(r.mean_s),
            format!("{:.2e}", k_fft.max_abs_diff(&k_true)),
            format!("{:.4}", fft_flops / 1e9),
        ]);

        // gradient descent at increasing iteration budgets
        for iters in [100usize, 800] {
            let mut eng = NativeEngine::new_fft_baseline();
            let mut k_gd = Matrix::zeros(n, n);
            let t0 = Instant::now();
            k_gd = distillation::distill_gradient_descent(&mut eng, &x, &y, iters, 1.5);
            let dt = t0.elapsed().as_secs_f64();
            let gd_flops = eng.take_trace().total_flops() as f64;
            table.row(&[
                format!("{n}x{n}"),
                format!("grad-descent x{iters}"),
                fmt_time(dt),
                format!("{:.2e}", k_gd.max_abs_diff(&k_true)),
                format!("{:.4}", gd_flops / 1e9),
            ]);
        }
    }
    table.print();
    println!(
        "claim check: the spectral solve is exact in ~3 transforms while GD is still\n\
         ~0.7 away after 800 iterations and 100-1000x the FLOPs — realistic inputs\n\
         are ill-conditioned (dominant DC mode), which is precisely the paper's\n\
         'numerous iterations of time-consuming computations' argument (§I)."
    );
}
