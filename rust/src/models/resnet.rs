//! ResNet-50 layer stack (He et al.) at the paper's malware-trace input
//! resolution.  The MIRAI detector consumes 64×64 trace images (each
//! row a register, each column a clock cycle — see Fig. 12), so the
//! stack is instantiated at 64×64 rather than ImageNet's 224×224.

use crate::models::layers::{LayerSpec, ModelSpec};

fn conv(h: usize, cin: usize, cout: usize, k: usize, stride: usize) -> LayerSpec {
    LayerSpec::Conv {
        h,
        w: h,
        cin,
        cout,
        k,
        stride,
    }
}

/// A bottleneck block: 1×1 reduce, 3×3, 1×1 expand (+ shortcut conv on
/// the first block of each stage).
fn bottleneck(
    layers: &mut Vec<LayerSpec>,
    h: usize,
    cin: usize,
    cmid: usize,
    stride: usize,
    with_shortcut: bool,
) {
    let cout = 4 * cmid;
    layers.push(conv(h, cin, cmid, 1, 1));
    layers.push(conv(h, cmid, cmid, 3, stride));
    layers.push(conv(h / stride, cmid, cout, 1, 1));
    if with_shortcut {
        layers.push(conv(h, cin, cout, 1, stride));
    }
    layers.push(LayerSpec::Elementwise {
        h: h / stride,
        w: h / stride,
        c: cout,
    });
}

/// ResNet-50: conv1 + [3, 4, 6, 3] bottleneck stages + FC.
pub fn resnet50() -> ModelSpec {
    let mut layers = Vec::new();
    // stem: 7×7/2 conv + pool on the 64×64 trace image
    layers.push(conv(64, 3, 64, 7, 2));
    layers.push(LayerSpec::Pool {
        h: 32,
        w: 32,
        c: 64,
        k: 2,
    });
    // stage 1 (x3): 16×16 ... (input 16 after stem+pool)
    let mut h = 16;
    bottleneck(&mut layers, h, 64, 64, 1, true);
    for _ in 0..2 {
        bottleneck(&mut layers, h, 256, 64, 1, false);
    }
    // stage 2 (x4)
    bottleneck(&mut layers, h, 256, 128, 2, true);
    h /= 2;
    for _ in 0..3 {
        bottleneck(&mut layers, h, 512, 128, 1, false);
    }
    // stage 3 (x6)
    bottleneck(&mut layers, h, 512, 256, 2, true);
    h /= 2;
    for _ in 0..5 {
        bottleneck(&mut layers, h, 1024, 256, 1, false);
    }
    // stage 4 (x3)
    bottleneck(&mut layers, h, 1024, 512, 2, true);
    h /= 2;
    for _ in 0..2 {
        bottleneck(&mut layers, h, 2048, 512, 1, false);
    }
    let _ = h;
    // head: global pool + binary malware classifier
    layers.push(LayerSpec::Dense {
        cin: 2048,
        cout: 2,
    });
    ModelSpec {
        name: "ResNet50",
        layers,
        input_dim: 64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_50ish_weight_layers() {
        // 1 stem + 3·3+4·3+6·3+3·3 bottleneck convs + 4 shortcuts + 1 fc
        let d = resnet50().depth();
        assert!(d >= 50 && d <= 58, "depth {d}");
    }

    #[test]
    fn param_count_near_25m() {
        let p = resnet50().total_params();
        // conv params are resolution-independent; FC is tiny here.
        assert!(p > 20_000_000 && p < 30_000_000, "{p}");
    }

    #[test]
    fn more_nodes_than_1000() {
        // paper: "ResNet50 ... consisting of >1000 nodes"
        assert!(resnet50().layers.len() > 50);
    }
}
