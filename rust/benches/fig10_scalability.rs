//! Fig. 10 — scalability of the three acceleration methods with matrix
//! size (distillation solve, sizes 16 … 1024), plus the p-core sweep:
//! the same 1024² solve sharded across a simulated TPU [`DevicePool`]
//! with a priced interconnect (Algorithm 1 end-to-end).
//!
//! Two series per device: the *simulated* device time (the paper's
//! figure) and — at every size, now that the plan-based FFT engine
//! makes 1024² tractable — the *measured* native Rust wallclock of
//! the same algorithm, grounding the simulation in real execution.
//! Paper shape: all curves grow with size; TPU >30x faster than CPU at
//! 1024²; near-linear (sub-linear only from merge traffic) scaling
//! with p thanks to data decomposition.
//!
//! The `sim_sharded_tpu_p{1,2,4,8}_1024` rows are deterministic and
//! tracked by the CI regression gate (`xai-accel bench-check`), as are
//! the heterogeneous-pool rows: `sim_hetero_pool_mixed8_1024` (the
//! {4×TPU, 2×GPU, 2×CPU} pool replaying the sharded 1024² solve on
//! throughput-weighted bands) and `sim_hetero_{blind,affinity}_mixed8`
//! (the mixed-workload placement sweep — cost-model affinity must beat
//! kind-blind least-loaded by ≥ 1.3×, enforced under `BENCH_ENFORCE`).
//!
//! Since PR 6 the gate also tracks the collective-plane rows
//! `sim_collective_{tpu8,tpu_gpu,fleet8}_1024`: one 1024²
//! distillation interpretation executed by typed collective groups
//! (grouped ops carrying their membership, per-hop ring pricing), with
//! the acceptance that the best group beats the best single lane by
//! ≥ 1.3× — the "one big request can use every device" claim made
//! deterministic.
//!
//! Since PR 7 the gate also tracks the multi-host rows
//! `sim_multihost_{inproc,2host,4host}_1024`: the same interpretation
//! with the chips split across simulated hosts behind the RDMA link
//! class, cross-host collectives priced as a hierarchical two-level
//! ring with per-byte wire serialization.  Acceptance: scale-out to 8
//! chips on 2 (or 4) hosts beats the single host's 4 local chips by
//! ≥ 1.3× despite the wire.
//!
//! Since PR 8 the gate also tracks the closed-loop serving rows
//! `sim_openloop_{static,adaptive,calibrated}_p99`: deterministic
//! open-loop bursty traffic on a {2×TPU, 2×GPU} plane with lane 0's
//! silicon 3× slower than its cost model claims.  Acceptance: the
//! measured-EWMA adaptive placement must deliver a p99 ≥ 1.3× better
//! than the static analytic prior, and a calibrated fleet must
//! reproduce the static run bit-for-bit (the corrections normalize to
//! exactly 1.0).

use std::time::Instant;
use xai_accel::bench::{json, BenchResult};
use xai_accel::coordinator::openloop::{simulate_open_loop, OpenLoopConfig};
use xai_accel::coordinator::router::{self, PlacementPolicy};
use xai_accel::hwsim::{self, DeviceKind, DevicePool};
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::trace::NativeEngine;
use xai_accel::util::rng::Rng;
use xai_accel::util::table::{fmt_time, Table};
use xai_accel::xai::{distillation, workloads};

/// The Fig. 10 mixed fleet: 4 TPU + 2 GPU + 2 CPU members.
const MIXED8: [DeviceKind; 8] = [
    DeviceKind::Tpu,
    DeviceKind::Tpu,
    DeviceKind::Tpu,
    DeviceKind::Tpu,
    DeviceKind::Gpu,
    DeviceKind::Gpu,
    DeviceKind::Cpu,
    DeviceKind::Cpu,
];

fn main() {
    let quick = xai_accel::bench::quick_requested();
    let sizes: &[usize] = if quick {
        &[16, 64, 256, 1024]
    } else {
        &[16, 32, 64, 128, 256, 512, 1024]
    };

    let mut table = Table::new("Fig. 10: distillation-solve time vs matrix size")
        .header(&[
            "size", "CPU(sim)", "GPU(sim)", "TPU(sim)", "TPU speedup", "native Rust (measured)",
        ]);
    let mut csv = String::from("size,cpu_s,gpu_s,tpu_s,native_s\n");
    let mut rng = Rng::new(5);

    for &n in sizes {
        let fft = workloads::distill_solve_trace_sched(n, workloads::Schedule::FftForm);
        let mm = workloads::distill_solve_trace_sched(n, workloads::Schedule::MatmulForm);
        let t: Vec<f64> = DeviceKind::all()
            .iter()
            .map(|&k| {
                let trace = if k == DeviceKind::Cpu { &fft } else { &mm };
                hwsim::device_for(k).replay(trace).time_s
            })
            .collect();

        // ground truth: measure the real algorithm natively (FFT form —
        // what this host actually runs fastest).  The plan-based engine
        // made every size tractable: building `y` warms the plan cache,
        // so the timed solve reflects steady-state serving cost.
        let native = {
            let x = Matrix::from_fn(n, n, |_, _| 3.0 + rng.gauss_f32());
            let y = circ_conv2(&x, &Matrix::identity_kernel(n, n));
            let mut eng = NativeEngine::new_fft_baseline();
            let t0 = Instant::now();
            let k = distillation::distill_fft(&mut eng, &x, &y, 1e-6);
            let dt = t0.elapsed().as_secs_f64();
            assert!(k.is_finite());
            dt
        };

        table.row(&[
            format!("{n}x{n}"),
            fmt_time(t[0]),
            fmt_time(t[1]),
            fmt_time(t[2]),
            format!("{:.1}x", t[0] / t[2]),
            fmt_time(native),
        ]);
        csv.push_str(&format!("{n},{},{},{},{native}\n", t[0], t[1], t[2]));
    }
    table.print();
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig10.csv", csv).ok();
    println!("paper shape: monotone growth; TPU >30x over CPU at 1024x1024");

    // ---- p-core sweep: Algorithm-1 sharded solve on a TPU pool ------
    // The distill solve at 1024², sharded across p single-core TPU
    // chips with an explicitly priced ICI (ring merges + scatter), in
    // quick mode too — these rows are the Fig. 10 scaling claim made
    // reproducible, and the CI gate tracks them.
    let n = 1024usize;
    let mut sweep = Table::new("Fig. 10 p-core sweep: sharded 1024² solve on a TPU DevicePool")
        .header(&["p", "time", "speedup", "compute", "collective"]);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut times = std::collections::HashMap::new();
    for p in [1usize, 2, 4, 8] {
        let pool = DevicePool::homogeneous(DeviceKind::Tpu, p);
        let rep = pool.replay_sharded(&workloads::distill_solve_trace_sharded(n, p));
        times.insert(p, rep.time_s);
        sweep.row(&[
            format!("{p}"),
            fmt_time(rep.time_s),
            format!("{:.1}x", times[&1] / rep.time_s),
            fmt_time(rep.compute_s),
            fmt_time(rep.collective_s),
        ]);
        // deterministic, machine-independent: tracked by bench-check
        results.push(BenchResult::point(
            &format!("sim_sharded_tpu_p{p}_1024"),
            rep.time_s,
        ));
    }
    sweep.print();
    let speedup = times[&1] / times[&8];
    let sweep_ok = speedup >= 3.0 && speedup < 8.0;
    println!(
        "acceptance (p=8 at least 3x over p=1, sub-linear from priced interconnect): {} ({speedup:.1}x)",
        if sweep_ok { "PASS" } else { "FAIL" }
    );

    // ---- heterogeneous pool: mixed members, weighted bands ----------
    // The same sharded 1024² solve on the {4×TPU, 2×GPU, 2×CPU} pool:
    // band stages are throughput-weighted (a CPU member takes a
    // sliver, the accelerators the bulk), collectives ride the ring's
    // weakest link.  The row is deterministic and CI-tracked.
    let mixed = DevicePool::mixed(&MIXED8);
    let homog = DevicePool::homogeneous(DeviceKind::Tpu, 8);
    let mut hetero = Table::new(format!(
        "Fig. 10 heterogeneous pool: sharded 1024² solve, {} vs homogeneous p8",
        mixed.label()
    ))
    .header(&["pool", "time", "compute", "collective", "vs 8xTPU"]);
    let trace_1024 = workloads::distill_solve_trace_sharded(n, 8);
    let rep_homog = homog.replay_sharded(&trace_1024);
    let rep_mixed = mixed.replay_sharded(&trace_1024);
    for (label, rep) in [("8xTPU", &rep_homog), (mixed.label().as_str(), &rep_mixed)] {
        hetero.row(&[
            label.to_string(),
            fmt_time(rep.time_s),
            fmt_time(rep.compute_s),
            fmt_time(rep.collective_s),
            format!("{:.2}x", rep.time_s / rep_homog.time_s),
        ]);
    }
    hetero.print();
    results.push(BenchResult::point("sim_hetero_pool_mixed8_1024", rep_mixed.time_s));

    // ---- placement sweep: affinity vs kind-blind on the mixed pool --
    // The deterministic mixed workload (distill-256² solves, fused
    // saliency/classify/IG batches, small Shapley builds) placed on
    // the mixed fleet's lanes under both policies; each lane drains at
    // its simulated service rate, makespan = last lane to finish.
    let profiles = router::mixed_workload_profiles(8);
    let blind =
        router::simulate_mixed_placement(&MIXED8, &profiles, PlacementPolicy::LeastLoaded);
    let affinity =
        router::simulate_mixed_placement(&MIXED8, &profiles, PlacementPolicy::Affinity);
    let gain = blind / affinity;
    let mut placement = Table::new(format!(
        "mixed-workload placement on {} ({} batches)",
        mixed.label(),
        profiles.len()
    ))
    .header(&["policy", "makespan", "vs blind"]);
    placement.row(&["least-loaded (kind-blind)".into(), fmt_time(blind), "1.00x".into()]);
    placement.row(&[
        "affinity (cost model)".into(),
        fmt_time(affinity),
        format!("{gain:.2}x"),
    ]);
    placement.print();
    results.push(BenchResult::point("sim_hetero_blind_mixed8", blind));
    results.push(BenchResult::point("sim_hetero_affinity_mixed8", affinity));
    let hetero_ok = gain >= 1.3;
    println!(
        "acceptance (affinity >= 1.3x over kind-blind on the mixed pool): {} ({gain:.2}x)",
        if hetero_ok { "PASS" } else { "FAIL" }
    );

    // ---- cross-lane collective groups: one request, every device ----
    // The PR 6 plane: a single 1024² distillation interpretation
    // (solve + occlusion sweep) priced as a typed collective group —
    // grouped ops carry their membership, merges are per-hop over each
    // member's own link class — against the best single lane running
    // the same request alone (sharded solve at p=1 + the per-block
    // unfused sweep, the pre-collective serving path).  Deterministic
    // rows, CI-tracked.
    let block = 256usize;
    let single_profile = {
        let mut t = workloads::distill_solve_trace_sharded(n, 1);
        t.extend(&workloads::contribution_trace_sched(
            n,
            block,
            workloads::Schedule::FftForm,
        ));
        t
    };
    let (single_kind, single_s) = DeviceKind::all()
        .iter()
        .map(|&k| {
            (k, DevicePool::mixed(&[k]).replay_sharded(&single_profile).time_s)
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    let tpu8 = [DeviceKind::Tpu; 8];
    let tpu_gpu = [
        DeviceKind::Gpu,
        DeviceKind::Gpu,
        DeviceKind::Tpu,
        DeviceKind::Tpu,
        DeviceKind::Tpu,
        DeviceKind::Tpu,
    ];
    let groups: [(&str, &[DeviceKind]); 3] = [
        ("sim_collective_tpu8_1024", &tpu8),
        ("sim_collective_tpu_gpu_1024", &tpu_gpu),
        ("sim_collective_fleet8_1024", &MIXED8),
    ];
    let mut collective = Table::new(format!(
        "Fig. 10 collective groups: 1024² distill interpretation vs best single lane ({})",
        single_kind.name()
    ))
    .header(&["group", "time", "compute", "collective", "vs single"]);
    collective.row(&[
        format!("single {}", single_kind.name()),
        fmt_time(single_s),
        "-".into(),
        "-".into(),
        "1.00x".into(),
    ]);
    let mut best_collective = f64::INFINITY;
    for (name, members) in groups {
        let pool = DevicePool::mixed(members);
        let rep = pool.replay_sharded(&workloads::distill_interpretation_trace_collective(
            n, block, members,
        ));
        best_collective = best_collective.min(rep.time_s);
        collective.row(&[
            pool.label(),
            fmt_time(rep.time_s),
            fmt_time(rep.compute_s),
            fmt_time(rep.collective_s),
            format!("{:.2}x", single_s / rep.time_s),
        ]);
        results.push(BenchResult::point(name, rep.time_s));
    }
    collective.print();
    // the group planner, fed the full fleet, must land on the same
    // answer pricing does: weak-link members priced out, not filtered
    let chosen = hwsim::pool::plan_collective_group(&MIXED8, &|members| {
        DevicePool::mixed(members)
            .replay_sharded(&workloads::distill_interpretation_trace_collective(
                n, block, members,
            ))
            .time_s
    });
    println!(
        "planner choice from the {} fleet: {}",
        DevicePool::mixed(&MIXED8).label(),
        DevicePool::mixed(&chosen).label()
    );
    let collective_gain = single_s / best_collective;
    let collective_ok = collective_gain >= 1.3;
    println!(
        "acceptance (best collective >= 1.3x over best single lane at 1024x1024): {} ({collective_gain:.2}x)",
        if collective_ok { "PASS" } else { "FAIL" }
    );

    // ---- multi-host plane: scale-out over the priced wire -----------
    // PR 7: the same 1024² interpretation when the chips sit behind a
    // network.  One host's 4 local TPUs (chip links only) against 8
    // TPUs split across 2 and 4 hosts joined by the RDMA link class —
    // collectives crossing hosts pay the hierarchical two-level ring
    // (local gather, inter-host ring with per-byte serialization, local
    // fan-out).  Scale-out must win: twice the chips must buy >= 1.3x
    // even after the wire takes its cut.  Deterministic, CI-tracked.
    let rdma = hwsim::Interconnect::rdma();
    let host4 = [DeviceKind::Tpu; 4];
    let host8 = [DeviceKind::Tpu; 8];
    let mh_rows: [(&str, &str, DevicePool, &[DeviceKind]); 3] = [
        (
            "sim_multihost_inproc_1024",
            "1 host x 4 TPU (chip links)",
            DevicePool::mixed(&host4),
            &host4,
        ),
        (
            "sim_multihost_2host_1024",
            "2 hosts x 4 TPU (RDMA)",
            DevicePool::multihost(&host8, &[0, 0, 0, 0, 1, 1, 1, 1], rdma),
            &host8,
        ),
        (
            "sim_multihost_4host_1024",
            "4 hosts x 2 TPU (RDMA)",
            DevicePool::multihost(&host8, &[0, 0, 1, 1, 2, 2, 3, 3], rdma),
            &host8,
        ),
    ];
    let mut mh_table = Table::new(
        "Fig. 10 multi-host: 1024² distill interpretation, chips behind the wire",
    )
    .header(&["topology", "time", "compute", "collective", "vs 1 host"]);
    let mut mh_times: Vec<f64> = Vec::new();
    for (name, label, pool, members) in &mh_rows {
        let rep = pool.replay_sharded(&workloads::distill_interpretation_trace_collective(
            n, block, members,
        ));
        mh_table.row(&[
            label.to_string(),
            fmt_time(rep.time_s),
            fmt_time(rep.compute_s),
            fmt_time(rep.collective_s),
            format!(
                "{:.2}x",
                mh_times.first().copied().unwrap_or(rep.time_s) / rep.time_s
            ),
        ]);
        mh_times.push(rep.time_s);
        results.push(BenchResult::point(name, rep.time_s));
    }
    mh_table.print();
    let multihost_gain = mh_times[0] / mh_times[1].min(mh_times[2]);
    let multihost_ok = multihost_gain >= 1.3;
    println!(
        "acceptance (best multi-host >= 1.3x over the single host's local chips): {} ({multihost_gain:.2}x)",
        if multihost_ok { "PASS" } else { "FAIL" }
    );

    // ---- closed-loop serving: open-loop traffic, measured placement --
    // PR 8: deterministic virtual-time open-loop traffic (2000 bursty
    // mixed-kind arrivals at 70% of calibrated capacity) on a
    // {2×TPU, 2×GPU} plane where lane 0's silicon runs 3× slower than
    // its cost model claims.  The static analytic prior keeps feeding
    // the slow lane and its queue diverges; the measured-EWMA
    // corrections re-price it within a handful of batches and the
    // fleet routes around it.  All three rows are pure functions of
    // the config (no wallclock, no threads) and CI-tracked.
    let ol_static = simulate_open_loop(&OpenLoopConfig::miscalibrated(3.0, false));
    let ol_adaptive = simulate_open_loop(&OpenLoopConfig::miscalibrated(3.0, true));
    let ol_calib = simulate_open_loop(&OpenLoopConfig::miscalibrated(1.0, true));
    let ol_calib_static = simulate_open_loop(&OpenLoopConfig::miscalibrated(1.0, false));
    let mut serving = Table::new(
        "Fig. 10 serving loop: open-loop p99 on 2xTPU+2xGPU, lane 0 3x mis-calibrated",
    )
    .header(&["placement", "p50", "p99", "mean", "shed", "degraded"]);
    for (label, r) in [
        ("static prior (3x miscal)", &ol_static),
        ("adaptive EWMA (3x miscal)", &ol_adaptive),
        ("adaptive (calibrated)", &ol_calib),
    ] {
        serving.row(&[
            label.to_string(),
            fmt_time(r.p50_s),
            fmt_time(r.p99_s),
            fmt_time(r.mean_s),
            format!("{}", r.shed),
            format!("{}", r.degraded),
        ]);
    }
    serving.print();
    results.push(BenchResult::point("sim_openloop_static_p99", ol_static.p99_s));
    results.push(BenchResult::point("sim_openloop_adaptive_p99", ol_adaptive.p99_s));
    results.push(BenchResult::point("sim_openloop_calibrated_p99", ol_calib.p99_s));
    let serving_gain = ol_static.p99_s / ol_adaptive.p99_s;
    let serving_ok = serving_gain >= 1.3 && ol_calib == ol_calib_static;
    println!(
        "acceptance (adaptive p99 >= 1.3x better than static under 3x mis-calibration, \
         calibrated fleet bit-for-bit static): {} ({serving_gain:.2}x)",
        if serving_ok { "PASS" } else { "FAIL" }
    );

    let refs: Vec<&BenchResult> = results.iter().collect();
    json::emit(&refs);

    // BENCH_ENFORCE=1 turns the printed acceptance verdicts into an
    // exit code so a driver can hard-gate the scaling claims.
    let enforce = std::env::var("BENCH_ENFORCE")
        .map(|v| v == "1" || v == "true")
        .unwrap_or(false);
    if enforce && !(sweep_ok && hetero_ok && collective_ok && multihost_ok && serving_ok) {
        eprintln!(
            "acceptance FAILED: sharded sweep {speedup:.2}x (need >= 3x, sub-linear), \
             affinity gain {gain:.2}x (need >= 1.3x), \
             collective gain {collective_gain:.2}x (need >= 1.3x), \
             multi-host gain {multihost_gain:.2}x (need >= 1.3x), \
             serving-loop gain {serving_gain:.2}x (need >= 1.3x + calibrated bit-for-bit)"
        );
        std::process::exit(1);
    }
}
