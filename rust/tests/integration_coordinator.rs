//! Integration: the full coordinator pipeline under mixed live traffic,
//! failure injection, and shutdown.  Requires `make artifacts`.

use std::path::Path;
use xai_accel::coordinator::{
    batcher::BatchPolicy, Coordinator, CoordinatorConfig, Request, RequestKind, Response,
};
use xai_accel::data::cifar;
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::linalg::matrix::Matrix;
use xai_accel::util::rng::Rng;

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.txt").exists() {
        true
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        false
    }
}

fn start(executors: usize) -> Coordinator {
    let mut config = CoordinatorConfig::default();
    config.executors = executors;
    Coordinator::start(config).expect("coordinator start")
}

#[test]
fn classify_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let coord = start(1);
    let mut rng = Rng::new(0);
    let s = cifar::sample_class(3, &mut rng);
    match coord.call(Request::Classify { image: s.image }).unwrap() {
        Response::Logits(l) => {
            assert_eq!(l.len(), 4);
            let pred = l
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(pred, 3);
        }
        other => panic!("unexpected response {other:?}"),
    }
    coord.shutdown();
}

#[test]
fn distill_roundtrip_recovers_kernel() {
    if !have_artifacts() {
        return;
    }
    let coord = start(1);
    let mut rng = Rng::new(1);
    let x = Matrix::from_fn(16, 16, |_, _| 4.0 + rng.gauss_f32());
    let mut k_true = Matrix::zeros(16, 16);
    k_true.set(0, 0, 1.0);
    let y = circ_conv2(&x, &k_true);
    match coord.call(Request::Distill { x, y }).unwrap() {
        Response::Distillation {
            kernel,
            contributions,
        } => {
            assert!(kernel.max_abs_diff(&k_true) < 0.02);
            assert_eq!((contributions.rows, contributions.cols), (4, 4));
        }
        other => panic!("unexpected response {other:?}"),
    }
    coord.shutdown();
}

#[test]
fn invalid_requests_error_without_crashing_the_pipeline() {
    if !have_artifacts() {
        return;
    }
    let coord = start(1);
    // wrong image shape
    let bad = coord
        .call(Request::Classify {
            image: Matrix::zeros(7, 9),
        });
    assert!(bad.is_err());
    // wrong shapley table length
    let bad = coord.call(Request::Shapley {
        n: 6,
        values: vec![0.0; 10],
        names: (0..6).map(|i| format!("f{i}")).collect(),
    });
    assert!(bad.is_err());
    // unsupported distill size
    let bad = coord.call(Request::Distill {
        x: Matrix::zeros(20, 20),
        y: Matrix::zeros(20, 20),
    });
    assert!(bad.is_err());
    // out-of-range class
    let bad = coord.call(Request::IntGrad {
        image: Matrix::zeros(16, 16),
        baseline: Matrix::zeros(16, 16),
        class: 99,
    });
    assert!(bad.is_err());

    // ...and the pipeline still serves good requests afterwards
    let mut rng = Rng::new(2);
    let s = cifar::sample_class(0, &mut rng);
    assert!(coord.call(Request::Classify { image: s.image }).is_ok());
    coord.shutdown();
}

#[test]
fn batching_packs_classify_requests() {
    if !have_artifacts() {
        return;
    }
    let mut config = CoordinatorConfig::default();
    config.executors = 1;
    let mut policy = BatchPolicy::default();
    policy.max_wait = std::time::Duration::from_millis(20);
    config.policy = policy;
    let coord = Coordinator::start(config).unwrap();
    let mut rng = Rng::new(3);
    let pendings: Vec<_> = (0..32)
        .map(|i| {
            coord
                .submit(Request::Classify {
                    image: cifar::sample_class(i % 4, &mut rng).image,
                })
                .unwrap()
        })
        .collect();
    for p in pendings {
        p.wait().unwrap();
    }
    let mbs = coord.metrics().mean_batch_size();
    assert!(mbs > 2.0, "mean batch size {mbs} — batching inactive");
    coord.shutdown();
}

#[test]
fn two_executors_serve_concurrently() {
    if !have_artifacts() {
        return;
    }
    let coord = start(2);
    let mut rng = Rng::new(4);
    let pendings: Vec<_> = (0..48)
        .map(|i| {
            coord
                .submit(Request::Saliency {
                    image: cifar::sample_class(i % 4, &mut rng).image,
                    class: i % 4,
                })
                .unwrap()
        })
        .collect();
    let mut ok = 0;
    for p in pendings {
        if matches!(p.wait(), Ok(Response::Heatmap(h)) if h.is_finite()) {
            ok += 1;
        }
    }
    assert_eq!(ok, 48);
    assert_eq!(coord.metrics().completed(), 48);
    coord.shutdown();
}

#[test]
fn shutdown_rejects_new_requests() {
    if !have_artifacts() {
        return;
    }
    let coord = start(1);
    let mut rng = Rng::new(5);
    let img = cifar::sample_class(0, &mut rng).image;
    coord.call(Request::Classify { image: img.clone() }).unwrap();
    coord.shutdown();
    // A second coordinator still starts cleanly after the first's death
    // (no leaked global state).
    let coord2 = start(1);
    assert!(coord2.call(Request::Classify { image: img }).is_ok());
    coord2.shutdown();
}

#[test]
fn mixed_traffic_order_independent_correctness() {
    if !have_artifacts() {
        return;
    }
    let coord = start(2);
    let mut rng = Rng::new(6);
    // interleave kinds; every response must match its request kind
    let mut pendings = Vec::new();
    for i in 0..40 {
        let req = match i % 3 {
            0 => Request::Classify {
                image: cifar::sample_class(i % 4, &mut rng).image,
            },
            1 => Request::Saliency {
                image: cifar::sample_class(i % 4, &mut rng).image,
                class: i % 4,
            },
            _ => Request::Shapley {
                n: 6,
                values: rng.gauss_vec(64),
                names: (0..6).map(|j| format!("f{j}")).collect(),
            },
        };
        pendings.push((i, coord.submit(req).unwrap()));
    }
    for (i, p) in pendings {
        let resp = p.wait().unwrap();
        match i % 3 {
            0 => assert!(matches!(resp, Response::Logits(_))),
            1 => assert!(matches!(resp, Response::Heatmap(_))),
            _ => assert!(matches!(resp, Response::Attribution(_))),
        }
    }
    coord.shutdown();
}
