//! Executor workers: each owns a full PJRT registry (its "core") and
//! its own work queue (its device lane).
//!
//! `PjRtClient` is not `Send`, so registries cannot be shared; instead
//! every worker thread compiles its own copy of the artifacts at
//! startup.  This mirrors the paper's Algorithm 1 topology: `p`
//! independent cores, each executing sub-tasks "without requiring any
//! data exchange between cores", with results merged by the reply
//! channels.  Since PR 4 the cores are real scheduling entities: the
//! router places each batch on ONE device's queue (cost-model
//! affinity over the lane's device class since PR 5), and
//! requests above [`crate::coordinator::decomposition::SHARD_THRESHOLD`]
//! split/execute/merge through the native backend's sharded kernels —
//! a pool-width band plan executed on scoped core threads inside the
//! owning executor (the simulated Algorithm-1 cores), recording the
//! `ShardedFft2`/collective ops that `hwsim::pool::DevicePool` prices
//! as a true multi-chip topology.
//!
//! # Readiness contract
//!
//! Every worker reports its startup outcome on the `ready` channel as
//! `(worker_id, result)` and then drops its sender.  **Worker 0 is the
//! readiness sentinel**: [`await_readiness`] returns worker 0's result
//! and nothing else — another worker's `Ok` arriving first can no
//! longer mask a worker-0 artifact-load failure (the bug in the
//! previous single-message protocol, where `Coordinator::start` gated
//! on whichever worker happened to report first).  Non-sentinel
//! failures are logged; they surface operationally as reduced
//! throughput, not as a startup error.

use crate::coordinator::batcher::Batch;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::native::NativeBackend;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::router;
use crate::hwsim::DeviceKind;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Startup report of one worker: `(worker_id, load result)`.
pub type ReadySignal = (usize, crate::error::Result<()>);

/// Which execution engine a worker may bring up.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendMode {
    /// Prefer the compiled PJRT registry; fall back to the native
    /// fused-batch backend when artifacts cannot load (e.g. the
    /// offline image, where the `xla` bindings are stubbed).
    #[default]
    Auto,
    /// Compiled artifacts or startup failure — the pre-fused behavior.
    PjrtOnly,
    /// Native fused-batch execution only (no artifact load attempted).
    NativeOnly,
}

/// A worker's execution engine: either a compiled PJRT registry or the
/// native fused-batch backend ([`NativeBackend`]).  The router
/// dispatches whole batches against whichever is live.
pub enum ExecBackend {
    /// A compiled PJRT artifact registry.
    Pjrt(crate::runtime::ArtifactRegistry),
    /// The native fused-batch kernel backend.
    Native(NativeBackend),
}

impl ExecBackend {
    /// Bring up a backend under the given mode.  `pool` is the device
    /// pool width — the native backend shards oversized requests
    /// across that many cores (Algorithm 1).
    pub fn bring_up(
        mode: BackendMode,
        dir: &std::path::Path,
        pool: usize,
    ) -> crate::error::Result<ExecBackend> {
        match mode {
            BackendMode::NativeOnly => {
                Ok(ExecBackend::Native(NativeBackend::new().with_shards(pool)))
            }
            BackendMode::PjrtOnly => {
                crate::runtime::ArtifactRegistry::load(dir).map(ExecBackend::Pjrt)
            }
            BackendMode::Auto => match crate::runtime::ArtifactRegistry::load(dir) {
                Ok(reg) => Ok(ExecBackend::Pjrt(reg)),
                Err(e) => {
                    eprintln!(
                        "xai-executor: artifacts unavailable ({e}); \
                         serving through the native fused-batch backend"
                    );
                    Ok(ExecBackend::Native(NativeBackend::new().with_shards(pool)))
                }
            },
        }
    }

    /// Short backend name for logs (`pjrt`/`native`).
    pub fn name(&self) -> &'static str {
        match self {
            ExecBackend::Pjrt(_) => "pjrt",
            ExecBackend::Native(_) => "native",
        }
    }
}

/// Spawn one executor thread per device queue in `work` (worker `i`
/// drains queue `i` — its own device lane, priced by the placement
/// layer as device class `kinds[i]`).
///
/// Returns the join handles; workers exit when their queue closes.
/// Each worker sends exactly one [`ReadySignal`] and drops its sender,
/// so the channel disconnects once every worker has reported.
pub fn spawn_executors(
    artifact_dir: PathBuf,
    backend: BackendMode,
    kinds: Vec<DeviceKind>,
    work: Vec<BoundedQueue<Batch>>,
    metrics: Arc<Metrics>,
    ready: mpsc::Sender<ReadySignal>,
) -> Vec<JoinHandle<()>> {
    assert_eq!(kinds.len(), work.len(), "one device descriptor per lane queue");
    let pool = work.len();
    work.into_iter()
        .zip(kinds)
        .enumerate()
        .map(|(i, (queue, kind))| {
            let metrics = metrics.clone();
            let dir = artifact_dir.clone();
            let ready = ready.clone();
            std::thread::Builder::new()
                .name(format!("xai-executor-{i}"))
                .spawn(move || executor_loop(i, kind, backend, &dir, pool, queue, metrics, ready))
                .expect("spawn executor")
        })
        .collect()
}

/// Block until the sentinel (worker 0) has reported, and return its
/// result.  Reports from other workers are drained and — on failure —
/// logged, never returned.  If the channel disconnects before worker 0
/// reports (e.g. it panicked before sending), that is a startup error.
pub fn await_readiness(ready: &mpsc::Receiver<ReadySignal>) -> crate::error::Result<()> {
    for (id, result) in ready.iter() {
        if id == 0 {
            return result;
        }
        if let Err(e) = result {
            eprintln!("xai-executor-{id}: startup failed (non-sentinel): {e}");
        }
    }
    Err(crate::error::Error::Coordinator(
        "no executor came up: readiness channel closed before worker 0 reported".into(),
    ))
}

#[allow(clippy::too_many_arguments)]
fn executor_loop(
    id: usize,
    kind: DeviceKind,
    mode: BackendMode,
    dir: &std::path::Path,
    pool: usize,
    work: BoundedQueue<Batch>,
    metrics: Arc<Metrics>,
    ready: mpsc::Sender<ReadySignal>,
) {
    // Each worker brings up its own backend (a PJRT registry is its own
    // "core" and is not Send), reports the outcome once, and releases
    // the readiness channel.
    let backend = match ExecBackend::bring_up(mode, dir, pool) {
        Ok(b) => {
            let simd = crate::linalg::simd::active();
            eprintln!(
                "executor {id} ({kind}-class lane): {} backend up, simd={} ({} f32 lanes)",
                b.name(),
                simd.name(),
                crate::linalg::simd::lanes_f32(simd)
            );
            let _ = ready.send((id, Ok(())));
            drop(ready);
            b
        }
        Err(e) => {
            eprintln!("executor {id} ({kind}-class lane): failed to bring up backend: {e}");
            let _ = ready.send((id, Err(e)));
            // Close this device's lane so the placement layer stops
            // routing batches to a worker that will never drain them
            // (the batcher marks the lane dead on the closed-push),
            // then drain anything that already landed: dropping the
            // envelopes disconnects their reply channels, so waiting
            // clients get "worker dropped the request" instead of
            // hanging on a queue nobody will ever pop.
            work.close();
            while work.pop().is_some() {}
            return;
        }
    };
    while let Some(mut batch) = work.pop() {
        if let Some(stage) = batch.collective.take() {
            // Cross-lane collective member stage: this lane computes
            // its band of a multi-lane job (the job's last member
            // answers the envelope).  Counts toward lane busy time and
            // backlog, not toward batching efficiency — the job's
            // request completes once, on the merging member.
            let started = Instant::now();
            stage.run();
            metrics.record_device_batch(id, started.elapsed());
            continue;
        }
        let n = batch.envelopes.len();
        metrics.record_batch(n);
        let started = Instant::now();
        let results = router::execute_batch(&backend, &batch);
        debug_assert_eq!(results.len(), n);
        // per-device accounting: this lane's backlog shrinks, its busy
        // time grows — the placement layer reads both
        metrics.record_device_batch(id, started.elapsed());
        // closed-loop feedback: one measured/predicted sample into the
        // lane's service EWMA (collective stages keep predicted_s at
        // 0.0 and are skipped — the group planner priced those).
        if batch.predicted_s > 0.0 {
            metrics.record_service_sample(id, batch.predicted_s, started.elapsed());
        }
        for (env, result) in batch.envelopes.into_iter().zip(results) {
            let ok = result.is_ok();
            let latency = env.enqueued_at.elapsed();
            let queue_wait = latency.saturating_sub(started.elapsed());
            if ok {
                metrics.record_complete(env.request.kind(), latency, queue_wait);
                metrics.record_tier(env.tier);
            } else {
                metrics.record_failure();
            }
            // a dropped receiver just means the client went away
            let _ = env.reply.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn sentinel_failure_not_masked_by_earlier_ok() {
        // Worker 1 comes up first and reports Ok; worker 0 then fails.
        // The old protocol returned the first message (Ok) — the gate
        // must key on worker 0 specifically.
        let (tx, rx) = mpsc::channel();
        tx.send((1, Ok(()))).unwrap();
        tx.send((0, Err(Error::Artifact("bad manifest".into()))))
            .unwrap();
        drop(tx);
        assert!(await_readiness(&rx).is_err());
    }

    #[test]
    fn sentinel_ok_before_other_failure() {
        // Reverse order: worker 0 is healthy, a later worker fails —
        // startup succeeds (degraded capacity is an operational issue).
        let (tx, rx) = mpsc::channel();
        tx.send((0, Ok(()))).unwrap();
        tx.send((2, Err(Error::Artifact("bad manifest".into()))))
            .unwrap();
        drop(tx);
        assert!(await_readiness(&rx).is_ok());
    }

    #[test]
    fn backend_bring_up_modes() {
        let missing = std::path::Path::new("definitely-missing-artifacts");
        // native mode never touches the registry
        let native = ExecBackend::bring_up(BackendMode::NativeOnly, missing, 4).unwrap();
        assert_eq!(native.name(), "native");
        // auto mode degrades to native when artifacts cannot load
        let auto = ExecBackend::bring_up(BackendMode::Auto, missing, 4).unwrap();
        assert_eq!(auto.name(), "native");
        // pjrt-only surfaces the load failure (offline stub or missing dir)
        assert!(ExecBackend::bring_up(BackendMode::PjrtOnly, missing, 4).is_err());
    }

    #[test]
    fn failed_bring_up_closes_its_device_queue() {
        // A worker that cannot bring up its backend must close its
        // lane, so the placement layer marks it dead instead of
        // enqueueing batches no one will ever drain.
        let (tx, rx) = mpsc::channel();
        let work: Vec<BoundedQueue<Batch>> =
            (0..2).map(|_| BoundedQueue::new(2)).collect();
        let handles = spawn_executors(
            PathBuf::from("definitely-missing-artifacts"),
            BackendMode::PjrtOnly,
            vec![DeviceKind::Tpu, DeviceKind::Cpu],
            work.clone(),
            Arc::new(Metrics::with_devices(2)),
            tx,
        );
        for h in handles {
            let _ = h.join();
        }
        drop(rx);
        assert!(work.iter().all(|q| q.is_closed()));
    }

    #[test]
    fn disconnect_before_sentinel_is_an_error() {
        let (tx, rx) = mpsc::channel::<ReadySignal>();
        tx.send((1, Ok(()))).unwrap();
        drop(tx);
        let err = await_readiness(&rx).unwrap_err();
        assert!(err.to_string().contains("worker 0"), "{err}");
    }
}
