//! Roofline analysis — L1 kernel efficiency estimates.
//!
//! Pallas runs under `interpret=True` here, so real-TPU wallclock is
//! unavailable; instead we estimate MXU utilization and VMEM residency
//! *structurally* from the kernel's BlockSpec tiling, exactly as
//! DESIGN.md §Hardware-Adaptation prescribes.  EXPERIMENTS.md §Perf
//! reports these numbers for each shipped kernel.

use crate::hwsim::systolic::SystolicArray;

/// VMEM capacity of a TPU core (bytes). TPUv2: 16 MiB.
pub const VMEM_BYTES: usize = 16 * 1024 * 1024;

/// Structural description of a tiled matmul kernel (one grid step).
#[derive(Debug, Clone, Copy)]
pub struct KernelTiling {
    /// Output tile rows/cols and contraction tile.
    pub bm: usize,
    /// Tile width (columns of B per tile).
    pub bn: usize,
    /// Tile depth (reduction length per tile).
    pub bk: usize,
    /// Number of input/output planes resident per grid step (e.g. the
    /// complex matmul holds 4 inputs + 2 accumulators = 6).
    pub planes: usize,
}

impl KernelTiling {
    /// VMEM bytes resident per grid step (f32), including the
    /// double-buffer copy Mosaic inserts for the streamed inputs.
    pub fn vmem_bytes(&self, double_buffered: bool) -> usize {
        let tile = self.bm.max(self.bk) * self.bn.max(self.bk) * 4;
        let base = self.planes * tile;
        if double_buffered {
            base + (self.planes - 2).max(1) * tile // outputs not double-buffered
        } else {
            base
        }
    }

    /// Does the schedule fit VMEM (with double buffering)?
    pub fn fits_vmem(&self) -> bool {
        self.vmem_bytes(true) <= VMEM_BYTES
    }

    /// MXU utilization of the tile-level matmul on the given array.
    pub fn mxu_utilization(&self, mxu: &SystolicArray) -> f64 {
        mxu.utilization(self.bm, self.bk, self.bn)
    }
}

/// Roofline-attainable fraction of peak for a kernel with the given
/// arithmetic intensity (flops/byte) on (peak flops, bandwidth).
pub fn attainable_fraction(intensity: f64, peak_flops: f64, bw: f64) -> f64 {
    let bound = (intensity * bw).min(peak_flops);
    bound / peak_flops
}

/// Report rows for the kernels shipped in python/compile/kernels/.
pub fn shipped_kernel_report() -> Vec<(String, KernelTiling, f64, bool)> {
    let mxu = SystolicArray::default();
    let kernels = [
        // (name, tiling): planes counted from the kernel signatures.
        ("dft_matmul.complex_matmul (128³ tiles)", KernelTiling { bm: 128, bn: 128, bk: 128, planes: 6 }),
        ("spectral_div (128² tiles)", KernelTiling { bm: 128, bn: 128, bk: 1, planes: 6 }),
        ("shapley_matvec (128³ tiles)", KernelTiling { bm: 128, bn: 128, bk: 128, planes: 3 }),
        ("ig_path (1×128 reduce tiles)", KernelTiling { bm: 1, bn: 128, bk: 128, planes: 4 }),
        ("vandermonde_build (128² tiles)", KernelTiling { bm: 128, bn: 128, bk: 1, planes: 2 }),
        ("occlusion (128² reduce tiles)", KernelTiling { bm: 128, bn: 128, bk: 1, planes: 3 }),
    ];
    kernels
        .iter()
        .map(|(name, t)| (name.to_string(), *t, t.mxu_utilization(&mxu), t.fits_vmem()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shipped_kernels_fit_vmem() {
        for (name, t, _, fits) in shipped_kernel_report() {
            assert!(fits, "{name} overflows VMEM: {} B", t.vmem_bytes(true));
        }
    }

    #[test]
    fn tile_128_underfills_256_array() {
        // A 128-tile on a 256 array uses at most 1/4 of the cells; the
        // report must reflect that honestly.
        let t = KernelTiling { bm: 128, bn: 128, bk: 128, planes: 6 };
        let u = t.mxu_utilization(&SystolicArray::default());
        assert!(u < 0.26, "{u}");
    }

    #[test]
    fn attainable_is_memory_bound_at_low_intensity() {
        // intensity 1 flop/B on (100 GF/s, 10 GB/s) => 10% of peak
        let f = attainable_fraction(1.0, 100e9, 10e9);
        assert!((f - 0.1).abs() < 1e-9);
        // very high intensity hits the compute roof
        assert_eq!(attainable_fraction(1e6, 100e9, 10e9), 1.0);
    }

    #[test]
    fn vmem_math() {
        let t = KernelTiling { bm: 128, bn: 128, bk: 128, planes: 6 };
        // 6 × 64 KiB = 384 KiB base
        assert_eq!(t.vmem_bytes(false), 6 * 128 * 128 * 4);
    }
}
