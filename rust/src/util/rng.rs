//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used by the synthetic data generators and the property-test harness.
//! Both algorithms are public-domain reference implementations
//! (Blackman & Vigna); determinism across runs is a hard requirement for
//! reproducible experiments, which is why we do not depend on OS entropy.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed a SplitMix64 stream.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single integer.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Standard normal as f32.
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Vector of standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent child generator.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
