//! Property tests for the plan-based FFT engine: the planned transforms
//! must agree with the two independent oracles — the matmul-form DFT
//! (Eq. 14, a different algorithm entirely) and the direct O((MN)²)
//! circular convolution — across mixed sizes (powers of two, odd,
//! prime, and the 224 ImageNet edge) and thread counts {1, 2, 4}, and
//! must conserve energy (Parseval) at 256×256.

use xai_accel::linalg::conv::{circ_conv2, circ_conv2_direct};
use xai_accel::linalg::dft;
use xai_accel::linalg::fft;
use xai_accel::linalg::matrix::{CMatrix, Matrix};
use xai_accel::linalg::shard::plan_splits;
use xai_accel::util::prop::check_cases;
use xai_accel::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

#[test]
fn planned_fft2_matches_matmul_dft_across_sizes_and_threads() {
    let mut rng = Rng::new(100);
    let cases: Vec<(usize, usize)> = vec![(8, 8), (9, 7), (13, 13), (12, 20), (17, 5), (16, 32)];
    check_cases("planned fft2 == matmul DFT", &cases, |&(m, n)| {
        let x = CMatrix::from_real(&Matrix::random(m, n, &mut rng));
        let oracle = dft::dft2_matmul(&x);
        let plan = fft::plan2(m, n);
        for threads in THREADS {
            let fast = plan.fft2(&x, threads);
            assert!(
                fast.max_abs_diff(&oracle) < 1e-3,
                "{m}x{n} threads={threads}: {}",
                fast.max_abs_diff(&oracle)
            );
        }
    });
}

#[test]
fn planned_ifft2_matches_matmul_idft() {
    let mut rng = Rng::new(101);
    let cases: Vec<(usize, usize)> = vec![(8, 8), (9, 7), (15, 4), (7, 13)];
    check_cases("planned ifft2 == matmul IDFT", &cases, |&(m, n)| {
        let x = CMatrix::from_real(&Matrix::random(m, n, &mut rng));
        let oracle = dft::idft2_matmul(&x);
        let plan = fft::plan2(m, n);
        for threads in THREADS {
            let fast = plan.ifft2(&x, threads);
            assert!(
                fast.max_abs_diff(&oracle) < 1e-3,
                "{m}x{n} threads={threads}"
            );
        }
    });
}

#[test]
fn planned_fft2_matches_matmul_dft_at_224() {
    // The VGG/ResNet input edge: 224 = 2^5·7 exercises Bluestein at
    // padded length 512 in both dimensions, under every thread count.
    let mut rng = Rng::new(102);
    let x = CMatrix::from_real(&Matrix::random(224, 224, &mut rng));
    let oracle = dft::dft2_matmul(&x);
    let plan = fft::plan2(224, 224);
    for threads in THREADS {
        let fast = plan.fft2(&x, threads);
        assert!(
            fast.max_abs_diff(&oracle) < 5e-3,
            "224x224 threads={threads}: {}",
            fast.max_abs_diff(&oracle)
        );
    }
}

#[test]
fn rfft2_matches_complex_path_across_sizes_and_threads() {
    let mut rng = Rng::new(103);
    let cases: Vec<(usize, usize)> = vec![(8, 8), (9, 7), (13, 16), (5, 5), (224, 12)];
    check_cases("rfft2 == fft2∘from_real", &cases, |&(m, n)| {
        let x = Matrix::random(m, n, &mut rng);
        let plan = fft::plan2(m, n);
        let oracle = plan.fft2(&CMatrix::from_real(&x), 1);
        for threads in THREADS {
            let fast = plan.rfft2(&x, threads);
            assert!(
                fast.max_abs_diff(&oracle) < 1e-4,
                "{m}x{n} threads={threads}"
            );
        }
    });
}

#[test]
fn planned_convolution_matches_direct_oracle() {
    let mut rng = Rng::new(104);
    let cases: Vec<(usize, usize)> = vec![(4, 4), (6, 10), (7, 7), (9, 5), (16, 16), (13, 8)];
    check_cases("planned conv == direct conv", &cases, |&(m, n)| {
        let x = Matrix::random(m, n, &mut rng);
        let k = Matrix::random(m, n, &mut rng);
        let slow = circ_conv2_direct(&x, &k);
        // public path (auto threads)
        let fast = circ_conv2(&x, &k);
        assert!(fast.max_abs_diff(&slow) < 1e-3, "{m}x{n}: public path");
        // explicit thread counts through the plan API
        let plan = fft::plan2(m, n);
        let scale = ((m * n) as f32).sqrt();
        for threads in THREADS {
            let mut fx = plan.rfft2(&x, threads);
            let fk = plan.rfft2(&k, threads);
            for (a, &b) in fx.data.iter_mut().zip(&fk.data) {
                *a = (*a * b).scale(scale);
            }
            plan.process(&mut fx, true, threads);
            assert!(
                fx.real().max_abs_diff(&slow) < 1e-3,
                "{m}x{n} threads={threads}"
            );
        }
    });
}

#[test]
fn sharded_rfft2_matches_single_plan_at_256() {
    // The sharding-layer acceptance: Algorithm-1 banded execution must
    // be bit-consistent (≤ 1e-4) with the single-plan transform at the
    // serving threshold size, for even AND uneven core counts (p = 7
    // gives bands of 37/36 rows — the odd-band solo-row path).
    let mut rng = Rng::new(106);
    let x = Matrix::random(256, 256, &mut rng);
    let plan = fft::plan2(256, 256);
    let want = plan.rfft2(&x, 1);
    for p in [1usize, 2, 4, 7] {
        let got = fft::rfft2_sharded(&plan, &x, &plan_splits(256, p));
        assert!(
            got.max_abs_diff(&want) < 1e-4,
            "p={p}: {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn sharded_complex_transform_matches_process_at_256() {
    let mut rng = Rng::new(107);
    let orig = CMatrix::from_real(&Matrix::random(256, 256, &mut rng));
    let plan = fft::plan2(256, 256);
    let want = plan.fft2(&orig, 1);
    for p in [2usize, 7] {
        let bands = plan_splits(256, p);
        let mut got = orig.clone();
        fft::process_sharded(&plan, &mut got, false, &bands);
        assert!(got.max_abs_diff(&want) < 1e-4, "forward p={p}");
        fft::process_sharded(&plan, &mut got, true, &bands);
        assert!(got.max_abs_diff(&orig) < 1e-4, "roundtrip p={p}");
    }
}

#[test]
fn heterogeneous_weighted_bands_match_single_plan_at_256() {
    // The PR 5 acceptance: a heterogeneous pool sizes bands by
    // per-core throughput (a TPU member takes most of the lines, a CPU
    // member a sliver) — those *uneven, cost-model-derived* band plans
    // must stay bit-consistent (≤ 1e-4) with the unsharded transform
    // at the serving threshold size.  Runs the real mixed-fleet
    // weights, not synthetic ones.
    use xai_accel::hwsim::{DeviceKind, DevicePool};
    use xai_accel::linalg::shard::{compact, plan_splits_weighted};
    use xai_accel::trace::Op;
    let pool = DevicePool::mixed(&[
        DeviceKind::Tpu,
        DeviceKind::Tpu,
        DeviceKind::Tpu,
        DeviceKind::Tpu,
        DeviceKind::Gpu,
        DeviceKind::Gpu,
        DeviceKind::Cpu,
        DeviceKind::Cpu,
    ]);
    let probe = Op::BatchedFft2 { b: 256, m: 1, n: 256 };
    let weights = pool.stage_weights(8, &probe);
    let bands = compact(&plan_splits_weighted(256, &weights));
    assert!(bands.len() >= 2, "mixed weights must yield real bands: {bands:?}");
    let mut rng = Rng::new(108);
    let x = Matrix::random(256, 256, &mut rng);
    let plan = fft::plan2(256, 256);
    let want = plan.rfft2(&x, 1);
    let got = fft::rfft2_sharded(&plan, &x, &bands);
    assert!(
        got.max_abs_diff(&want) < 1e-4,
        "weighted bands {bands:?}: {}",
        got.max_abs_diff(&want)
    );
    // and the full sharded 256² solve round-trips through the same
    // weighted bands: K = F⁻¹(F(Y)∘conj(F(X))/(|F(X)|²+eps))·1/√(MN)
    let k_true = Matrix::identity_kernel(256, 256);
    let y = circ_conv2(&x, &k_true);
    // (the solve's trailing 1/√(MN) rescale is the same constant on
    // both paths, so the comparison elides it)
    let fx = fft::rfft2_sharded(&plan, &x, &bands);
    let fy = fft::rfft2_sharded(&plan, &y, &bands);
    let mut q = xai_accel::linalg::conv::spectral_divide(&fy, &fx, 1e-6);
    fft::process_sharded(&plan, &mut q, true, &bands);
    let k_sharded = q.real();
    // unsharded reference solve
    let fx1 = plan.rfft2(&x, 1);
    let fy1 = plan.rfft2(&y, 1);
    let mut q1 = xai_accel::linalg::conv::spectral_divide(&fy1, &fx1, 1e-6);
    plan.process(&mut q1, true, 1);
    let k_unsharded = q1.real();
    assert!(
        k_sharded.max_abs_diff(&k_unsharded) < 1e-4,
        "sharded 256² solve drifted: {}",
        k_sharded.max_abs_diff(&k_unsharded)
    );
}

#[test]
fn parseval_at_256() {
    let mut rng = Rng::new(105);
    let x = Matrix::random(256, 256, &mut rng);
    let plan = fft::plan2(256, 256);
    let e_time: f64 = x.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
    for threads in THREADS {
        let f = plan.rfft2(&x, threads);
        let e_freq: f64 = f.data.iter().map(|z| z.norm_sqr() as f64).sum();
        assert!(
            ((e_time - e_freq) / e_time).abs() < 1e-3,
            "threads={threads}: {e_time} vs {e_freq}"
        );
    }
}
