//! Table IV — outcome-interpretation time, Shapley Values.
//!
//! 10 games per benchmark in structure-vector form (§III-B): value
//! tables built by model evaluation, then φ = T·v as one batched
//! matmul.  Paper shape: TPU 16x/CPU + 3x/GPU on VGG19; smaller
//! absolute times on ResNet50 (fewer features in the malware detector).

use xai_accel::bench::{json, BenchResult};
use xai_accel::hwsim::{self, DeviceKind};
use xai_accel::models::Benchmark;
use xai_accel::util::table::{fmt_speedup, Table};
use xai_accel::xai::workloads;

fn main() {
    let games = 10;
    let mut results: Vec<BenchResult> = Vec::new();
    let mut table = Table::new("Table IV: interpretation time (s), Shapley Values")
        .header(&["model", "CPU", "GPU", "TPU", "Impro./CPU", "Impro./GPU"]);
    let mut csv = String::from("model,cpu_s,gpu_s,tpu_s\n");

    // (model, players): the image classifier explains 16 coarse
    // super-pixel features; the malware detector uses the 6 HPCs.
    for (bench, players) in [(Benchmark::Vgg19, 16usize), (Benchmark::ResNet50, 6)] {
        let spec = bench.spec();
        // value function evaluated through the distilled surrogate
        // (~1% of a full forward), as §III-A feeds §III-B
        let trace =
            workloads::shapley_interpretation_trace(players, games, spec.total_flops() / 100);
        let t: Vec<f64> = DeviceKind::all()
            .iter()
            .map(|&k| hwsim::device_for(k).replay(&trace).time_s)
            .collect();
        table.row(&[
            format!("{} (n={players})", spec.name),
            format!("{:.3}", t[0]),
            format!("{:.3}", t[1]),
            format!("{:.4}", t[2]),
            fmt_speedup(t[0] / t[2]),
            fmt_speedup(t[1] / t[2]),
        ]);
        csv.push_str(&format!("{},{},{},{}\n", spec.name, t[0], t[1], t[2]));
        // deterministic simulated rows — tracked by the CI bench gate
        for (kind, &secs) in DeviceKind::all().iter().zip(&t) {
            results.push(BenchResult::point(
                &format!(
                    "sim_{}_table4_{}",
                    kind.name().to_lowercase(),
                    spec.name.to_lowercase()
                ),
                secs,
            ));
        }
    }
    table.print();
    let refs: Vec<&BenchResult> = results.iter().collect();
    json::emit(&refs);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/table4.csv", csv).ok();
    println!("paper shape: VGG19 row much slower than ResNet50 row (2^16 vs 2^6 table)");
}
