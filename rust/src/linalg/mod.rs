//! Dense linear-algebra substrate.
//!
//! This is both (a) the *CPU baseline* the paper compares against and
//! (b) the native reference the runtime artifacts are validated with.
//! Everything is row-major `f32` (matching the PJRT literals) with
//! complex arithmetic carried by [`complex::C32`].
//!
//! The paper's central trick — Eq. 14, a 2-D DFT as two matmuls — lives
//! in [`dft`]; the plan-based FFT engine (cached twiddle/bit-reversal
//! tables, Bluestein off powers of two, threaded batched 2-D
//! transforms) lives in [`fft`] as the asymptotically-optimal CPU
//! comparator.
//!
//! The inner loops of the hot kernels — GEMM, FFT butterflies, the
//! convolution spectrum product — are served by the
//! runtime-dispatched SIMD layer in [`simd`] (AVX2/FMA on x86_64,
//! NEON on aarch64, portable scalar fallback everywhere).

pub mod block;
pub mod complex;
pub mod conv;
pub mod dft;
pub mod fft;
pub mod matrix;
pub mod shard;
pub mod simd;
pub mod solve;
pub mod vandermonde;
