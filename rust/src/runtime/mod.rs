//! PJRT runtime: load AOT artifacts, compile once, execute on the hot
//! path.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py`):
//! `HloModuleProto::from_text_file` reassigns instruction ids, which is
//! what makes jax ≥ 0.5 output loadable by xla_extension 0.5.1.
//! Executables are compiled once per model variant at startup and owned
//! by an [`ArtifactRegistry`]; the coordinator calls [`Executable::run`]
//! from worker threads.

pub mod client;
pub mod manifest;
pub mod pjrt_stub;

pub use client::{
    distill_collective_variant, distill_sharded_variant, select_distill_variant,
    ArtifactRegistry, Executable,
};
pub use manifest::{ArtifactSpec, Manifest, Shape};
