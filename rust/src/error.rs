//! Crate-wide error type.
//!
//! `Display`/`Error` are implemented by hand: this offline build
//! carries zero external dependencies (no `thiserror`), and the
//! message strings below are part of the public surface tests rely on,
//! so they are kept verbatim.

use std::fmt;

// The PJRT bindings are stubbed offline; see `runtime::pjrt_stub`.
use crate::runtime::pjrt_stub as xla;

/// Unified error for runtime, coordinator, and configuration failures.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failures surfaced from the `xla` bindings.
    Xla(String),

    /// Artifact manifest missing or malformed.
    Artifact(String),

    /// Shape mismatch between a request and the compiled executable.
    Shape {
        /// What the executable / validator required.
        expected: String,
        /// What the request actually carried.
        got: String,
    },

    /// Coordinator queue closed or over capacity.
    Coordinator(String),

    /// Configuration file / CLI errors.
    Config(String),

    /// Numerical failure (singular system, non-finite values).
    Numeric(String),

    /// Wire-format encode/decode failure on the transport plane.
    Wire(crate::transport::wire::WireError),

    /// Filesystem errors (artifact loading, bench output).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Shape { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Numeric(msg) => write!(f, "numeric error: {msg}"),
            Error::Wire(e) => write!(f, "wire error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::transport::wire::WireError> for Error {
    fn from(e: crate::transport::wire::WireError) -> Self {
        Error::Wire(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;
