//! Algorithm-1 band assignments — the sharding vocabulary shared by
//! every layer of the execution plane.
//!
//! The paper's Algorithm 1 splits the 2-D transform's rows (then
//! columns) across `p` cores.  An [`Assignment`] names one core's
//! contiguous band of lines; [`plan_splits`] produces the balanced
//! partition.  The same types drive the planned-FFT band stages
//! ([`crate::linalg::fft::Fft2Plan::rfft2_sharded`]), the coordinator's
//! split/execute/merge layer ([`crate::coordinator::decomposition`]),
//! and the pool replay ([`crate::hwsim::pool::DevicePool`]) — one
//! decomposition vocabulary, three layers.

/// Line-range (row or column band) assignment for one core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub start: usize,
    pub len: usize,
}

/// Split `total` items over `p` workers as evenly as possible
/// (Algorithm 1's "Split M/p rows from x").  Workers beyond `total`
/// get no assignment; every returned band is non-empty, contiguous,
/// and the bands partition `0..total` in order.
pub fn plan_splits(total: usize, p: usize) -> Vec<Assignment> {
    assert!(p > 0);
    let p = p.min(total.max(1));
    let base = total / p;
    let extra = total % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        if len == 0 {
            continue;
        }
        out.push(Assignment { start, len });
        start += len;
    }
    out
}

/// Assert that `assignments` is exactly the contiguous, in-order,
/// non-empty partition of `0..total` that the band stages require.
pub fn validate_partition(assignments: &[Assignment], total: usize) {
    let mut expect = 0;
    for a in assignments {
        assert!(
            a.start == expect && a.len > 0,
            "assignments must be a contiguous in-order partition \
             (expected start {expect}, got {a:?})"
        );
        expect += a.len;
    }
    assert_eq!(expect, total, "assignments must cover all {total} lines");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn splits_cover_exactly() {
        check("splits partition the range", 30, |rng: &mut Rng| {
            let total = rng.int_range(1, 100) as usize;
            let p = rng.int_range(1, 16) as usize;
            let plan = plan_splits(total, p);
            validate_partition(&plan, total);
            // balanced within 1
            let min = plan.iter().map(|a| a.len).min().unwrap();
            let max = plan.iter().map(|a| a.len).max().unwrap();
            assert!(max - min <= 1);
        });
    }

    #[test]
    fn more_workers_than_rows_is_fine() {
        let plan = plan_splits(3, 8);
        assert_eq!(plan.len(), 3);
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn validate_rejects_gaps() {
        validate_partition(
            &[
                Assignment { start: 0, len: 2 },
                Assignment { start: 3, len: 1 },
            ],
            4,
        );
    }
}
