//! Fast Fourier transform: iterative radix-2 Cooley-Tukey plus a direct
//! O(n²) DFT fallback for non-power-of-two lengths.
//!
//! Unitary normalization throughout (1/sqrt(n) per transform) to match
//! the paper's Eq. 7 and the Pallas kernels.  This is the *CPU
//! baseline*: the asymptotically best a general-purpose core can do,
//! against which the matmul-form TPU path (Eq. 14) is compared.

use crate::linalg::complex::C32;
use crate::linalg::matrix::CMatrix;

/// In-place unitary FFT of a power-of-two-length buffer.
pub fn fft_pow2(buf: &mut [C32]) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft_pow2 requires power-of-two length");
    fft_raw(buf, false);
    let s = 1.0 / (n as f32).sqrt();
    for z in buf.iter_mut() {
        *z = z.scale(s);
    }
}

/// In-place unitary inverse FFT of a power-of-two-length buffer.
pub fn ifft_pow2(buf: &mut [C32]) {
    let n = buf.len();
    assert!(n.is_power_of_two());
    fft_raw(buf, true);
    let s = 1.0 / (n as f32).sqrt();
    for z in buf.iter_mut() {
        *z = z.scale(s);
    }
}

/// Unnormalized iterative radix-2 Cooley-Tukey.
fn fft_raw(buf: &mut [C32], inverse: bool) {
    let n = buf.len();
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f32::consts::PI / len as f32;
        let wlen = C32::cis(ang);
        for start in (0..n).step_by(len) {
            let mut w = C32::ONE;
            for k in 0..len / 2 {
                let u = buf[start + k];
                let v = buf[start + k + len / 2] * w;
                buf[start + k] = u + v;
                buf[start + k + len / 2] = u - v;
                w = w * wlen;
            }
        }
        len <<= 1;
    }
}

/// Unitary DFT of arbitrary length (direct O(n²) when not a power of 2).
pub fn dft_any(input: &[C32], inverse: bool) -> Vec<C32> {
    let n = input.len();
    if n.is_power_of_two() {
        let mut buf = input.to_vec();
        if inverse {
            ifft_pow2(&mut buf);
        } else {
            fft_pow2(&mut buf);
        }
        return buf;
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let s = 1.0 / (n as f32).sqrt();
    (0..n)
        .map(|k| {
            let mut acc = C32::ZERO;
            for (m, &x) in input.iter().enumerate() {
                let ang = sign * 2.0 * std::f32::consts::PI * (k * m % n) as f32 / n as f32;
                acc += x * C32::cis(ang);
            }
            acc.scale(s)
        })
        .collect()
}

/// Unitary 2-D FFT: rows then columns (paper §III-D two-stage schedule).
pub fn fft2(x: &CMatrix) -> CMatrix {
    transform2(x, false)
}

/// Unitary inverse 2-D FFT.
pub fn ifft2(x: &CMatrix) -> CMatrix {
    transform2(x, true)
}

fn transform2(x: &CMatrix, inverse: bool) -> CMatrix {
    let (m, n) = (x.rows, x.cols);
    let mut out = CMatrix::zeros(m, n);
    // Stage 1: rows.
    for r in 0..m {
        let row: Vec<C32> = (0..n).map(|c| x.get(r, c)).collect();
        let t = dft_any(&row, inverse);
        for c in 0..n {
            out.set(r, c, t[c]);
        }
    }
    // Stage 2: columns.
    for c in 0..n {
        let col: Vec<C32> = (0..m).map(|r| out.get(r, c)).collect();
        let t = dft_any(&col, inverse);
        for r in 0..m {
            out.set(r, c, t[r]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut buf = vec![C32::ZERO; 8];
        buf[0] = C32::ONE;
        fft_pow2(&mut buf);
        let expect = 1.0 / (8f32).sqrt();
        for z in &buf {
            assert!((z.re - expect).abs() < 1e-6 && z.im.abs() < 1e-6);
        }
    }

    #[test]
    fn roundtrip_pow2() {
        let mut rng = Rng::new(0);
        let orig: Vec<C32> = (0..64)
            .map(|_| C32::new(rng.gauss_f32(), rng.gauss_f32()))
            .collect();
        let mut buf = orig.clone();
        fft_pow2(&mut buf);
        ifft_pow2(&mut buf);
        for (a, b) in orig.iter().zip(&buf) {
            assert!((*a - *b).abs() < 1e-4);
        }
    }

    #[test]
    fn dft_any_matches_fft_on_pow2() {
        let mut rng = Rng::new(1);
        let input: Vec<C32> = (0..16)
            .map(|_| C32::new(rng.gauss_f32(), rng.gauss_f32()))
            .collect();
        let direct = {
            // force the direct path via a manual computation at n=16
            let n = input.len();
            let s = 1.0 / (n as f32).sqrt();
            (0..n)
                .map(|k| {
                    let mut acc = C32::ZERO;
                    for (m, &x) in input.iter().enumerate() {
                        let ang = -2.0 * std::f32::consts::PI * (k * m) as f32 / n as f32;
                        acc += x * C32::cis(ang);
                    }
                    acc.scale(s)
                })
                .collect::<Vec<_>>()
        };
        let fast = dft_any(&input, false);
        for (a, b) in direct.iter().zip(&fast) {
            assert!((*a - *b).abs() < 1e-4);
        }
    }

    #[test]
    fn roundtrip_non_pow2() {
        let mut rng = Rng::new(2);
        let orig: Vec<C32> = (0..12)
            .map(|_| C32::new(rng.gauss_f32(), rng.gauss_f32()))
            .collect();
        let f = dft_any(&orig, false);
        let back = dft_any(&f, true);
        for (a, b) in orig.iter().zip(&back) {
            assert!((*a - *b).abs() < 1e-4);
        }
    }

    #[test]
    fn parseval_2d() {
        let mut rng = Rng::new(3);
        let x = CMatrix::from_real(&Matrix::random(8, 16, &mut rng));
        let f = fft2(&x);
        let e_time: f32 = x.data.iter().map(|z| z.norm_sqr()).sum();
        let e_freq: f32 = f.data.iter().map(|z| z.norm_sqr()).sum();
        assert!((e_time - e_freq).abs() / e_time < 1e-4);
    }

    #[test]
    fn fft2_roundtrip() {
        let mut rng = Rng::new(4);
        let x = CMatrix::from_real(&Matrix::random(16, 8, &mut rng));
        let back = ifft2(&fft2(&x));
        assert!(back.max_abs_diff(&x) < 1e-4);
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::new(5);
        let a = CMatrix::from_real(&Matrix::random(8, 8, &mut rng));
        let b = CMatrix::from_real(&Matrix::random(8, 8, &mut rng));
        let sum = CMatrix::from_fn(8, 8, |r, c| a.get(r, c) + b.get(r, c));
        let lhs = fft2(&sum);
        let fa = fft2(&a);
        let fb = fft2(&b);
        let rhs = CMatrix::from_fn(8, 8, |r, c| fa.get(r, c) + fb.get(r, c));
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }
}
