//! End-to-end system driver (the EXPERIMENTS.md §E2E run).
//!
//! Run with:  cargo run --release --example serve_e2e [-- --requests N]
//!
//! Proves all three layers compose: loads the **real trained MicroCNN**
//! and the XAI pipelines from the AOT artifacts (L2+L1, compiled HLO),
//! serves a mixed batched workload through the Rust coordinator (L3),
//! verifies the *numerics* of every response against the native
//! oracles, and reports latency/throughput + batching efficiency.

use xai_accel::coordinator::{Coordinator, CoordinatorConfig, Request, Response};
use xai_accel::data::{cifar, counters};
use xai_accel::linalg::conv::circ_conv2;
use xai_accel::prelude::*;
use xai_accel::util::rng::Rng;
use xai_accel::xai::shapley;

fn main() -> xai_accel::error::Result<()> {
    let args = xai_accel::cli::Args::from_env();
    let requests = args.get_usize("requests", 96)?;
    let executors = args.get_usize("executors", 2)?;

    let mut config = CoordinatorConfig::default();
    config.executors = executors;
    println!("[e2e] starting coordinator ({executors} executors, PJRT CPU)...");
    let coord = Coordinator::start(config)?;

    let mut rng = Rng::new(2024);
    let started = std::time::Instant::now();

    // ---- build a mixed workload with known ground truth ----------------
    enum Check {
        Classify { label: usize },
        Distill { k_true: Matrix },
        Shapley { exact: Vec<f32> },
        IntGrad { label: usize },
    }
    let mut pendings = Vec::new();
    for i in 0..requests {
        let (req, check) = match i % 4 {
            0 => {
                let s = cifar::sample_class(i % 4, &mut rng);
                (
                    Request::Classify {
                        image: s.image.clone(),
                    },
                    Check::Classify { label: s.label },
                )
            }
            1 => {
                let x = Matrix::from_fn(16, 16, |_, _| 3.0 + rng.gauss_f32());
                let mut k_true = Matrix::zeros(16, 16);
                k_true.set(0, 0, 0.7);
                k_true.set(1, 1, 0.3);
                let y = circ_conv2(&x, &k_true);
                (Request::Distill { x, y }, Check::Distill { k_true })
            }
            2 => {
                let s = counters::sample(counters::ProgramClass::Spectre, &mut rng);
                let benign = [0.15f32, 0.10, 0.50, 0.20, 0.40, 0.25];
                let game = shapley::ValueTable::from_fn(6, |sub| {
                    let mut f = benign;
                    for j in 0..6 {
                        if sub & (1 << j) != 0 {
                            f[j] = s.features[j];
                        }
                    }
                    counters::detector_score(&f)
                });
                let exact = shapley::shapley_exact(&game);
                (
                    Request::Shapley {
                        n: 6,
                        values: game.values.clone(),
                        names: counters::FEATURES.iter().map(|s| s.to_string()).collect(),
                    },
                    Check::Shapley { exact },
                )
            }
            _ => {
                let s = cifar::sample_class(i % 4, &mut rng);
                (
                    Request::IntGrad {
                        baseline: Matrix::zeros(16, 16),
                        class: s.label,
                        image: s.image.clone(),
                    },
                    Check::IntGrad { label: s.label },
                )
            }
        };
        pendings.push((coord.submit(req)?, check));
    }

    // ---- await + verify -------------------------------------------------
    let mut ok = 0usize;
    let mut verified = 0usize;
    let total = pendings.len();
    for (p, check) in pendings {
        let resp = match p.wait() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("[e2e] request failed: {e}");
                continue;
            }
        };
        ok += 1;
        let good = match (resp, check) {
            (Response::Logits(l), Check::Classify { label }) => {
                let pred = l
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                pred == label
            }
            (Response::Distillation { kernel, .. }, Check::Distill { k_true }) => {
                kernel.max_abs_diff(&k_true) < 0.05
            }
            (Response::Attribution(a), Check::Shapley { exact }) => a
                .scores
                .iter()
                .zip(&exact)
                .all(|(got, want)| (got - want).abs() < 1e-3),
            (Response::Heatmap(h), Check::IntGrad { label }) => {
                // IG must highlight the labeled quadrant above average
                let (r0, c0) = cifar::quadrant_origin(label);
                let mut quad = 0f32;
                let mut all = 0f32;
                for r in 0..16 {
                    for c in 0..16 {
                        let v = h.get(r, c).abs();
                        all += v;
                        if r >= r0 && r < r0 + 8 && c >= c0 && c < c0 + 8 {
                            quad += v;
                        }
                    }
                }
                quad / all.max(1e-9) > 0.25 // quadrant is 25% of pixels
            }
            _ => false,
        };
        if good {
            verified += 1;
        }
    }
    let elapsed = started.elapsed().as_secs_f64();

    println!("\n[e2e] served    : {ok}/{total} requests");
    println!("[e2e] verified  : {verified}/{ok} responses numerically correct");
    println!(
        "[e2e] throughput: {:.1} req/s over {:.2}s",
        total as f64 / elapsed,
        elapsed
    );
    print!("{}", coord.metrics().report());
    let mean_batch = coord.metrics().mean_batch_size();
    coord.shutdown();

    assert!(ok == total, "all requests must be served");
    assert!(
        verified as f64 >= 0.9 * ok as f64,
        "≥90% of responses must verify against the oracles"
    );
    assert!(mean_batch > 1.5, "batching must actually batch");
    println!("\n[e2e] PASS — three layers compose: Pallas kernels → JAX AOT → PJRT → coordinator");
    Ok(())
}
