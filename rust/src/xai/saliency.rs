//! Vanilla gradient saliency (Simonyan et al.) — the Fig. 14(b)
//! comparator and the degenerate case of model distillation the paper
//! notes in §II-B ("if we choose linear regression ... the entire model
//! distillation process degenerates to the Saliency Map method").

use crate::linalg::matrix::Matrix;
use crate::trace::NativeEngine;
use crate::xai::attribution::Attribution;
use crate::xai::integrated_gradients::GradientProvider;

/// |∂F/∂x| at the input — no path integration.
pub fn saliency<G: GradientProvider>(model: &G, x: &[f32]) -> Attribution {
    let g = model.gradient(x);
    Attribution::unnamed(g.iter().map(|v| v.abs()).collect())
}

/// Spectrally smooth ONE gradient heatmap (circular convolution with
/// `smooth`), engine-traced — the per-request leg the fused batch path
/// is checked against.
pub fn smooth_heatmap(eng: &mut NativeEngine, heatmap: &Matrix, smooth: &Matrix) -> Matrix {
    let out = smooth_heatmaps_batch(eng, std::slice::from_ref(heatmap), smooth);
    out.into_iter().next().unwrap()
}

/// Fused batched heatmap smoothing: `b` gradient heatmaps circularly
/// convolved with one shared kernel through a single shared FFT plan
/// ([`crate::linalg::conv::circ_conv2_batch`]: batched forward `rfft2`
/// with the row lines of all heatmaps sharded together, one
/// Hadamard/rescale pass, batched inverse).  Records two `BatchedFft2`
/// ops and the element-wise product — and **no kernel-spectrum
/// `Fft2`**: the smoothing kernel is a process-lifetime constant whose
/// spectrum is served from
/// [`crate::linalg::conv::cached_kernel_spectrum`], so its one-time
/// transform amortizes to zero in steady-state serving and is excluded
/// from the per-batch trace convention.  Results are identical to
/// smoothing each heatmap alone.
pub fn smooth_heatmaps_batch(
    eng: &mut NativeEngine,
    heatmaps: &[Matrix],
    smooth: &Matrix,
) -> Vec<Matrix> {
    assert!(!heatmaps.is_empty());
    let (m, n) = (smooth.rows, smooth.cols);
    for h in heatmaps {
        assert_eq!((h.rows, h.cols), (m, n));
    }
    let b = heatmaps.len();
    eng.trace.push(crate::trace::Op::BatchedFft2 { b, m, n });
    eng.trace.push(crate::trace::Op::Elementwise { elems: 2 * b * m * n });
    eng.trace.push(crate::trace::Op::BatchedFft2 { b, m, n });
    let refs: Vec<&Matrix> = heatmaps.iter().collect();
    crate::linalg::conv::circ_conv2_batch(&refs, smooth)
}

/// Signed input-times-gradient variant (a cheap IG proxy).
pub fn input_x_gradient<G: GradientProvider>(model: &G, x: &[f32]) -> Attribution {
    let g = model.gradient(x);
    Attribution::unnamed(g.iter().zip(x).map(|(gi, xi)| gi * xi).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Linear {
        w: Vec<f32>,
    }
    impl GradientProvider for Linear {
        fn value(&self, x: &[f32]) -> f32 {
            x.iter().zip(&self.w).map(|(a, b)| a * b).sum()
        }
        fn gradient(&self, _x: &[f32]) -> Vec<f32> {
            self.w.clone()
        }
    }

    #[test]
    fn saliency_of_linear_is_weight_magnitude() {
        let m = Linear {
            w: vec![2.0, -3.0, 0.5],
        };
        let a = saliency(&m, &[1.0, 1.0, 1.0]);
        assert_eq!(a.scores, vec![2.0, 3.0, 0.5]);
        assert_eq!(a.top_feature(), 1);
    }

    #[test]
    fn batched_smoothing_matches_circ_conv() {
        use crate::linalg::conv::circ_conv2;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let smooth = Matrix::random(16, 16, &mut rng);
        let maps: Vec<Matrix> =
            (0..4).map(|_| Matrix::random(16, 16, &mut rng)).collect();
        let mut eng = NativeEngine::new_fft_baseline();
        let fused = smooth_heatmaps_batch(&mut eng, &maps, &smooth);
        for (m, got) in maps.iter().zip(&fused) {
            let want = circ_conv2(m, &smooth);
            assert!(got.max_abs_diff(&want) < 1e-5);
        }
        // the trace carries the two fused transforms, not 2·B singles
        let fft_ops = eng
            .trace
            .ops
            .iter()
            .filter(|o| matches!(o, crate::trace::Op::BatchedFft2 { b: 4, .. }))
            .count();
        assert_eq!(fft_ops, 2);
        // ...and NO per-batch kernel-spectrum transform: the smooth
        // kernel is a process-lifetime constant served from the conv
        // spectrum cache, so the per-batch convention excludes it
        assert!(
            !eng
                .trace
                .ops
                .iter()
                .any(|o| matches!(o, crate::trace::Op::Fft2 { .. })),
            "kernel spectrum must not be re-priced per batch: {:?}",
            eng.trace.ops
        );
        assert_eq!(eng.trace.ops.len(), 3);
    }

    #[test]
    fn ixg_recovers_contribution_for_linear() {
        // For linear models, input×gradient == exact attribution.
        let m = Linear {
            w: vec![1.0, 2.0],
        };
        let a = input_x_gradient(&m, &[3.0, -1.0]);
        assert_eq!(a.scores, vec![3.0, -2.0]);
        assert!((a.total() - m.value(&[3.0, -1.0])).abs() < 1e-6);
    }
}
