//! Layer-level model descriptions with FLOP / parameter accounting.

/// One layer of a convolutional classifier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerSpec {
    /// 2-D convolution: `out = conv(in)` on an H×W feature map.
    Conv {
        /// Input feature-map height.
        h: usize,
        /// Input feature-map width.
        w: usize,
        /// Input channels.
        cin: usize,
        /// Output channels.
        cout: usize,
        /// Square kernel edge.
        k: usize,
        /// Convolution stride.
        stride: usize,
    },
    /// Fully connected.
    Dense {
        /// Input features.
        cin: usize,
        /// Output features.
        cout: usize,
    },
    /// Max/avg pooling (no params; counted as elementwise work).
    Pool {
        /// Input feature-map height.
        h: usize,
        /// Input feature-map width.
        w: usize,
        /// Channels.
        c: usize,
        /// Pooling window edge.
        k: usize,
    },
    /// Batch norm / activation over an H×W×C tensor.
    Elementwise {
        /// Tensor height.
        h: usize,
        /// Tensor width.
        w: usize,
        /// Tensor channels.
        c: usize,
    },
}

impl LayerSpec {
    /// Multiply-add FLOPs for one forward pass (2 flops per MAC).
    pub fn flops(&self) -> u64 {
        match *self {
            LayerSpec::Conv {
                h,
                w,
                cin,
                cout,
                k,
                stride,
            } => {
                let oh = h / stride;
                let ow = w / stride;
                2 * (oh * ow * cout * cin * k * k) as u64
            }
            LayerSpec::Dense { cin, cout } => 2 * (cin * cout) as u64,
            LayerSpec::Pool { h, w, c, k } => (h * w * c * k * k / 4) as u64,
            LayerSpec::Elementwise { h, w, c } => (h * w * c) as u64,
        }
    }

    /// Trainable parameters.
    pub fn params(&self) -> u64 {
        match *self {
            LayerSpec::Conv {
                cin, cout, k, ..
            } => (cin * cout * k * k + cout) as u64,
            LayerSpec::Dense { cin, cout } => (cin * cout + cout) as u64,
            _ => 0,
        }
    }

    /// Output activation elements.
    pub fn activations(&self) -> u64 {
        match *self {
            LayerSpec::Conv {
                h, w, cout, stride, ..
            } => ((h / stride) * (w / stride) * cout) as u64,
            LayerSpec::Dense { cout, .. } => cout as u64,
            LayerSpec::Pool { h, w, c, k } => ((h / k) * (w / k) * c) as u64,
            LayerSpec::Elementwise { h, w, c } => (h * w * c) as u64,
        }
    }
}

/// A whole model as an ordered layer stack.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Human-readable model name (e.g. `VGG16`).
    pub name: &'static str,
    /// Layers in forward order.
    pub layers: Vec<LayerSpec>,
    /// Input feature dimension seen by the XAI algorithms (e.g. the
    /// image edge for distillation's X matrix).
    pub input_dim: usize,
}

impl ModelSpec {
    /// Forward-pass FLOPs summed over all layers.
    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops()).sum()
    }

    /// Trainable parameters summed over all layers.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Backward pass ≈ 2× forward (grad w.r.t. weights + activations).
    pub fn backward_flops(&self) -> u64 {
        2 * self.total_flops()
    }

    /// Number of layers.
    pub fn depth(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::Conv { .. } | LayerSpec::Dense { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flops() {
        // 3x3 conv, 8->16 ch, 32x32, stride 1: 2·32·32·16·8·9
        let l = LayerSpec::Conv {
            h: 32,
            w: 32,
            cin: 8,
            cout: 16,
            k: 3,
            stride: 1,
        };
        assert_eq!(l.flops(), 2 * 32 * 32 * 16 * 8 * 9);
        assert_eq!(l.params(), 8 * 16 * 9 + 16);
    }

    #[test]
    fn dense_params() {
        let l = LayerSpec::Dense { cin: 512, cout: 10 };
        assert_eq!(l.params(), 512 * 10 + 10);
        assert_eq!(l.flops(), 2 * 512 * 10);
    }

    #[test]
    fn stride_halves_output() {
        let s1 = LayerSpec::Conv {
            h: 32,
            w: 32,
            cin: 4,
            cout: 4,
            k: 3,
            stride: 1,
        };
        let s2 = LayerSpec::Conv {
            h: 32,
            w: 32,
            cin: 4,
            cout: 4,
            k: 3,
            stride: 2,
        };
        assert_eq!(s1.flops(), 4 * s2.flops());
    }
}
