//! VGG16 / VGG19 layer stacks (Simonyan & Zisserman), at CIFAR input
//! resolution (32×32) as the paper trains them on CIFAR-100.

use crate::models::layers::{LayerSpec, ModelSpec};

fn conv(h: usize, cin: usize, cout: usize) -> LayerSpec {
    LayerSpec::Conv {
        h,
        w: h,
        cin,
        cout,
        k: 3,
        stride: 1,
    }
}

fn pool(h: usize, c: usize) -> LayerSpec {
    LayerSpec::Pool { h, w: h, c, k: 2 }
}

/// VGG-19: 16 conv + 3 FC.
pub fn vgg19() -> ModelSpec {
    let mut layers = Vec::new();
    // block 1: 2×conv64 @32
    layers.push(conv(32, 3, 64));
    layers.push(conv(32, 64, 64));
    layers.push(pool(32, 64));
    // block 2: 2×conv128 @16
    layers.push(conv(16, 64, 128));
    layers.push(conv(16, 128, 128));
    layers.push(pool(16, 128));
    // block 3: 4×conv256 @8
    layers.push(conv(8, 128, 256));
    for _ in 0..3 {
        layers.push(conv(8, 256, 256));
    }
    layers.push(pool(8, 256));
    // block 4: 4×conv512 @4
    layers.push(conv(4, 256, 512));
    for _ in 0..3 {
        layers.push(conv(4, 512, 512));
    }
    layers.push(pool(4, 512));
    // block 5: 4×conv512 @2
    for _ in 0..4 {
        layers.push(conv(2, 512, 512));
    }
    layers.push(pool(2, 512));
    // classifier
    layers.push(LayerSpec::Dense { cin: 512, cout: 4096 });
    layers.push(LayerSpec::Dense { cin: 4096, cout: 4096 });
    layers.push(LayerSpec::Dense { cin: 4096, cout: 100 });
    ModelSpec {
        name: "VGG19",
        layers,
        input_dim: 32,
    }
}

/// VGG-16: 13 conv + 3 FC (the Fig. 8 comparator, "138M params" at
/// ImageNet scale; CIFAR-resolution here).
pub fn vgg16() -> ModelSpec {
    let mut layers = Vec::new();
    layers.push(conv(32, 3, 64));
    layers.push(conv(32, 64, 64));
    layers.push(pool(32, 64));
    layers.push(conv(16, 64, 128));
    layers.push(conv(16, 128, 128));
    layers.push(pool(16, 128));
    layers.push(conv(8, 128, 256));
    layers.push(conv(8, 256, 256));
    layers.push(conv(8, 256, 256));
    layers.push(pool(8, 256));
    layers.push(conv(4, 256, 512));
    layers.push(conv(4, 512, 512));
    layers.push(conv(4, 512, 512));
    layers.push(pool(4, 512));
    layers.push(conv(2, 512, 512));
    layers.push(conv(2, 512, 512));
    layers.push(conv(2, 512, 512));
    layers.push(pool(2, 512));
    layers.push(LayerSpec::Dense { cin: 512, cout: 4096 });
    layers.push(LayerSpec::Dense { cin: 4096, cout: 4096 });
    layers.push(LayerSpec::Dense { cin: 4096, cout: 100 });
    ModelSpec {
        name: "VGG16",
        layers,
        input_dim: 32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_depth() {
        assert_eq!(vgg19().depth(), 19); // 16 conv + 3 fc
    }

    #[test]
    fn vgg16_depth() {
        assert_eq!(vgg16().depth(), 16);
    }

    #[test]
    fn vgg19_heavier_than_vgg16() {
        assert!(vgg19().total_flops() > vgg16().total_flops());
        assert!(vgg19().total_params() > vgg16().total_params());
    }

    #[test]
    fn param_counts_plausible() {
        // CIFAR-resolution VGG19: conv params identical to ImageNet
        // (20M), FC shrinks; total must land in the 20M–45M window.
        let p = vgg19().total_params();
        assert!(p > 20_000_000 && p < 60_000_000, "{p}");
    }
}
