//! Algorithm-1 band assignments — the sharding vocabulary shared by
//! every layer of the execution plane.
//!
//! The paper's Algorithm 1 splits the 2-D transform's rows (then
//! columns) across `p` cores.  An [`Assignment`] names one core's
//! contiguous band of lines; [`plan_splits`] produces the balanced
//! partition.  The same types drive the planned-FFT band stages
//! ([`crate::linalg::fft::Fft2Plan::rfft2_sharded`]), the coordinator's
//! split/execute/merge layer ([`crate::coordinator::decomposition`]),
//! and the pool replay ([`crate::hwsim::pool::DevicePool`]) — one
//! decomposition vocabulary, three layers.

/// Line-range (row or column band) assignment for one core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    /// First line (row or column index) of the band.
    pub start: usize,
    /// Number of contiguous lines in the band.
    pub len: usize,
}

/// How a collective group merges its partial results.
///
/// Every interconnect class the fleet models exposes a bucket ring, so
/// that is the only topology today; the enum exists so a plan can name
/// its merge shape explicitly instead of the pool assuming one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeTopology {
    /// Bucket ring: `p−1` steps, each moving `payload/p` per link.
    Ring,
}

/// A typed collective group: *which* devices cooperate on one sharded
/// request, the line band each member owns, and how the partial
/// results merge.  This is the explicit form of what the device pool
/// used to decide implicitly ("split over my own width, merge over my
/// own ring") — the coordinator, the pool replay, and the FFT band
/// executors all consume the same plan.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectivePlan {
    /// Device class of each member, in band order (member `i` owns
    /// `bands[i]`).
    pub members: Vec<crate::hwsim::DeviceKind>,
    /// Per-member contiguous line bands; a strict in-order partition of
    /// `0..total` (zero-share members are dropped at construction).
    pub bands: Vec<Assignment>,
    /// Merge topology for the interior collectives.
    pub merge: MergeTopology,
}

impl CollectivePlan {
    /// Balanced plan: `total` lines split evenly over `members`.
    /// Members beyond `total` are dropped (a 3-line problem over 8
    /// devices is a 3-member group).
    pub fn balanced(total: usize, members: &[crate::hwsim::DeviceKind]) -> Self {
        assert!(!members.is_empty(), "a collective group needs members");
        let bands = plan_splits(total.max(1), members.len());
        let members = members[..bands.len().min(members.len())].to_vec();
        Self {
            members,
            bands,
            merge: MergeTopology::Ring,
        }
    }

    /// Throughput-weighted plan: member `i` takes a band proportional
    /// to `weights[i]` (largest-remainder apportionment, same contract
    /// as [`plan_splits_weighted`]).  Members whose share rounds to
    /// zero are dropped from the group.
    pub fn from_weights(
        total: usize,
        members: &[crate::hwsim::DeviceKind],
        weights: &[f64],
    ) -> Self {
        assert_eq!(members.len(), weights.len(), "one weight per member");
        assert!(!members.is_empty(), "a collective group needs members");
        let raw = plan_splits_weighted(total, weights);
        let mut kept_members = Vec::new();
        let mut bands = Vec::new();
        for (kind, band) in members.iter().zip(&raw) {
            if band.len > 0 {
                kept_members.push(*kind);
                bands.push(*band);
            }
        }
        Self {
            members: kept_members,
            bands,
            merge: MergeTopology::Ring,
        }
    }

    /// Surviving members of the group (= band count).
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True when every member was dropped (e.g. a degrade with no
    /// survivors).
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Lines the plan covers (sum of band lengths).
    pub fn total_lines(&self) -> usize {
        self.bands.iter().map(|a| a.len).sum()
    }

    /// Assert the plan is a strict partition of `0..total` with one
    /// band per member — the invariant every executor relies on.
    pub fn validate(&self, total: usize) {
        assert_eq!(
            self.members.len(),
            self.bands.len(),
            "one band per member"
        );
        validate_partition(&self.bands, total);
    }

    /// Link traffic one ring merge of a `payload`-byte result costs:
    /// `payload·(p−1)` bytes cross the links in total, independent of
    /// how unevenly the bands are sized (conservation — the property
    /// test pins this).
    pub fn merge_bytes(&self, payload: u64) -> u64 {
        match self.merge {
            MergeTopology::Ring => payload * self.len().saturating_sub(1) as u64,
        }
    }

    /// Re-plan after losing members: survivors (marked `true` in
    /// `alive`, indexed like `members`) re-split `total` lines in
    /// proportion to their old band sizes, preserving the original
    /// throughput weighting.  Returns `None` when nobody survives.
    pub fn degrade(&self, total: usize, alive: &[bool]) -> Option<Self> {
        assert_eq!(alive.len(), self.members.len(), "one flag per member");
        let members: Vec<_> = self
            .members
            .iter()
            .zip(alive)
            .filter(|(_, &a)| a)
            .map(|(k, _)| *k)
            .collect();
        if members.is_empty() {
            return None;
        }
        let weights: Vec<f64> = self
            .bands
            .iter()
            .zip(alive)
            .filter(|(_, &a)| a)
            .map(|(b, _)| b.len.max(1) as f64)
            .collect();
        Some(Self::from_weights(total, &members, &weights))
    }
}

/// Split `total` items over `p` workers as evenly as possible
/// (Algorithm 1's "Split M/p rows from x").  Workers beyond `total`
/// get no assignment; every returned band is non-empty, contiguous,
/// and the bands partition `0..total` in order.
pub fn plan_splits(total: usize, p: usize) -> Vec<Assignment> {
    assert!(p > 0);
    let p = p.min(total.max(1));
    let base = total / p;
    let extra = total % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        if len == 0 {
            continue;
        }
        out.push(Assignment { start, len });
        start += len;
    }
    out
}

/// Split `total` lines over workers **proportionally to `weights`**
/// (per-core throughput — a GPU core takes a wider band than a CPU
/// core).  Returns exactly `weights.len()` assignments in worker
/// order, forming a contiguous in-order partition of `0..total`;
/// zero-length bands are legal here (a worker whose share rounds to
/// nothing sits the stage out) — [`compact`] drops them before the
/// strict band executors.  Largest-remainder apportionment keeps every
/// band within one line of its ideal `total·wᵢ/Σw` quota (the property
/// `weighted_splits_track_the_proportional_ideal` checks).
///
/// Non-finite or negative weights are rejected; an all-zero weight
/// vector degenerates to the balanced [`plan_splits`] partition.
pub fn plan_splits_weighted(total: usize, weights: &[f64]) -> Vec<Assignment> {
    assert!(!weights.is_empty(), "need at least one worker");
    assert!(
        weights.iter().all(|w| w.is_finite() && *w >= 0.0),
        "weights must be finite and non-negative: {weights:?}"
    );
    let p = weights.len();
    let sum: f64 = weights.iter().sum();
    if sum <= 0.0 {
        // no throughput signal: fall back to the balanced partition,
        // padded with empty tail bands so worker i still maps to band i
        let mut out = plan_splits(total.max(1), p);
        if total == 0 {
            out.clear();
        }
        while out.len() < p {
            out.push(Assignment {
                start: total,
                len: 0,
            });
        }
        return out;
    }
    // Largest-remainder apportionment: floor every quota, then hand the
    // leftover lines to the largest fractional remainders (ties to the
    // lowest worker index, so the result is deterministic).
    let quotas: Vec<f64> = weights.iter().map(|w| total as f64 * w / sum).collect();
    let mut lens: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let assigned: usize = lens.iter().sum();
    let mut order: Vec<usize> = (0..p).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &i in order.iter().take(total.saturating_sub(assigned)) {
        lens[i] += 1;
    }
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for len in lens {
        out.push(Assignment { start, len });
        start += len;
    }
    out
}

/// Drop zero-length bands from a weighted plan, yielding the strict
/// non-empty partition the band executors
/// ([`crate::linalg::fft::Fft2Plan::rfft2_sharded`] and friends)
/// require.  The surviving bands still partition `0..total` in order.
pub fn compact(assignments: &[Assignment]) -> Vec<Assignment> {
    assignments.iter().filter(|a| a.len > 0).copied().collect()
}

/// Assert that `assignments` is exactly the contiguous, in-order,
/// non-empty partition of `0..total` that the band stages require.
pub fn validate_partition(assignments: &[Assignment], total: usize) {
    let mut expect = 0;
    for a in assignments {
        assert!(
            a.start == expect && a.len > 0,
            "assignments must be a contiguous in-order partition \
             (expected start {expect}, got {a:?})"
        );
        expect += a.len;
    }
    assert_eq!(expect, total, "assignments must cover all {total} lines");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn splits_cover_exactly() {
        check("splits partition the range", 30, |rng: &mut Rng| {
            let total = rng.int_range(1, 100) as usize;
            let p = rng.int_range(1, 16) as usize;
            let plan = plan_splits(total, p);
            validate_partition(&plan, total);
            // balanced within 1
            let min = plan.iter().map(|a| a.len).min().unwrap();
            let max = plan.iter().map(|a| a.len).max().unwrap();
            assert!(max - min <= 1);
        });
    }

    #[test]
    fn more_workers_than_rows_is_fine() {
        let plan = plan_splits(3, 8);
        assert_eq!(plan.len(), 3);
    }

    #[test]
    fn weighted_splits_track_the_proportional_ideal() {
        // The satellite property: weighted bands are total-preserving,
        // contiguous, and within ONE line of the weighted-proportional
        // ideal — largest-remainder apportionment guarantees it.
        check("weighted splits", 60, |rng: &mut Rng| {
            let total = rng.int_range(0, 300) as usize;
            let p = rng.int_range(1, 12) as usize;
            // weight profiles spanning 3 orders of magnitude (the
            // TPU-vs-CPU throughput gap the mixed pools really see)
            let weights: Vec<f64> = (0..p)
                .map(|_| match rng.below(4) {
                    0 => 0.001,
                    1 => 0.1,
                    2 => 1.0,
                    _ => rng.int_range(1, 1000) as f64 / 100.0,
                })
                .collect();
            let plan = plan_splits_weighted(total, &weights);
            // one band per worker, in order, total-preserving
            assert_eq!(plan.len(), p);
            let mut expect = 0usize;
            for a in &plan {
                assert_eq!(a.start, expect, "bands must be contiguous in order");
                expect += a.len;
            }
            assert_eq!(expect, total, "bands must cover all lines");
            // within one line of the weighted-proportional ideal
            let sum: f64 = weights.iter().sum();
            for (a, w) in plan.iter().zip(&weights) {
                let ideal = total as f64 * w / sum;
                assert!(
                    (a.len as f64 - ideal).abs() < 1.0 + 1e-9,
                    "band {} lines vs ideal {ideal:.3} (w={w})",
                    a.len
                );
            }
            // compacting yields the strict partition the executors need
            let strict = compact(&plan);
            if total > 0 {
                validate_partition(&strict, total);
            } else {
                assert!(strict.is_empty());
            }
        });
    }

    #[test]
    fn equal_weights_degenerate_to_balanced_splits() {
        check("weighted == balanced at equal weights", 30, |rng: &mut Rng| {
            let total = rng.int_range(1, 200) as usize;
            let p = rng.int_range(1, 10) as usize;
            let weighted = compact(&plan_splits_weighted(total, &vec![1.0; p]));
            assert_eq!(weighted, plan_splits(total, p));
        });
    }

    #[test]
    fn zero_and_degenerate_weights() {
        // all-zero weights: no throughput signal, balanced fallback
        let plan = plan_splits_weighted(10, &[0.0, 0.0, 0.0]);
        assert_eq!(compact(&plan), plan_splits(10, 3));
        // a zero-weight member gets nothing; the rest share it all
        let plan = plan_splits_weighted(10, &[1.0, 0.0, 1.0]);
        assert_eq!(plan[1].len, 0);
        assert_eq!(plan[0].len + plan[2].len, 10);
        // zero lines: every band empty but worker-aligned
        let plan = plan_splits_weighted(0, &[2.0, 1.0]);
        assert_eq!(plan.len(), 2);
        assert!(plan.iter().all(|a| a.len == 0));
    }

    #[test]
    fn dominant_weight_takes_nearly_everything() {
        let plan = plan_splits_weighted(100, &[1000.0, 1.0, 1.0]);
        assert!(plan[0].len >= 98, "{plan:?}");
        assert_eq!(plan.iter().map(|a| a.len).sum::<usize>(), 100);
    }

    #[test]
    fn collective_plans_partition_and_conserve_merge_bytes() {
        use crate::hwsim::DeviceKind;
        // The satellite property: every constructed plan passes
        // validate_partition, and ring merge traffic is exactly
        // payload·(p−1) regardless of band skew.
        check("collective plan invariants", 40, |rng: &mut Rng| {
            let total = rng.int_range(1, 400) as usize;
            let p = rng.int_range(1, 8) as usize;
            let members: Vec<DeviceKind> = (0..p)
                .map(|_| match rng.below(3) {
                    0 => DeviceKind::Cpu,
                    1 => DeviceKind::Gpu,
                    _ => DeviceKind::Tpu,
                })
                .collect();
            let plan = if rng.below(2) == 0 {
                CollectivePlan::balanced(total, &members)
            } else {
                let weights: Vec<f64> = (0..p)
                    .map(|_| rng.int_range(1, 1000) as f64 / 10.0)
                    .collect();
                CollectivePlan::from_weights(total, &members, &weights)
            };
            plan.validate(total);
            assert_eq!(plan.total_lines(), total);
            let payload = rng.int_range(1, 1 << 20) as u64;
            assert_eq!(
                plan.merge_bytes(payload),
                payload * (plan.len() as u64 - 1),
                "ring merge traffic must conserve payload·(p−1)"
            );
        });
    }

    #[test]
    fn degraded_plans_rebalance_over_survivors() {
        use crate::hwsim::DeviceKind;
        let members = [DeviceKind::Tpu, DeviceKind::Gpu, DeviceKind::Cpu];
        let plan = CollectivePlan::from_weights(300, &members, &[10.0, 80.0, 10.0]);
        plan.validate(300);
        // lose the GPU member: survivors re-split all 300 lines
        let degraded = plan.degrade(300, &[true, false, true]).unwrap();
        degraded.validate(300);
        assert_eq!(degraded.members, vec![DeviceKind::Tpu, DeviceKind::Cpu]);
        // survivors keep their *relative* weighting (equal here)
        assert!(degraded.bands.iter().all(|b| b.len == 150));
        // nobody left: no plan
        assert!(plan.degrade(300, &[false, false, false]).is_none());
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn validate_rejects_gaps() {
        validate_partition(
            &[
                Assignment { start: 0, len: 2 },
                Assignment { start: 3, len: 1 },
            ],
            4,
        );
    }
}
